"""Fleet-scale federated rounds (DESIGN.md §12): seeded client sampling,
the quantized ZO uplink, and their composition with faults, checkpoints
and the sharded round — the invariants the K-in-the-thousands protocol
rests on:

* sampler determinism + bit-exact RNG state resume,
* ``sample_frac=1.0`` + identity codec == today's dense round bitwise
  (unsharded and under a 1x1 FLShardPlan),
* exact-replay quantization: the virtual path reconstructs bit-exactly
  from the encoded wire payload alone,
* CommLog bills encoded wire bytes for exactly the cohort,
* server state stays O(seeds + scalars) in K.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import CheckpointError
from repro.checkpoint.state import server_state_sizes
from repro.configs.base import FLConfig
from repro.configs.tiny import TINY
from repro.core import random_mask
from repro.core import virtual_path as VP
from repro.core.fl_step import make_fl_train_loop
from repro.core.gradip import gradip_matrix
from repro.core.quantize import IdentityCodec, IntCodec
from repro.core.sampling import ClientSampler
from repro.core.seeds import round_keys
from repro.core.server import Client, FederatedZO
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.fault import FaultPlan, RoundFaults

SPEC = TaskSpec(vocab=min(TINY.vocab, 512))


@pytest.fixture(scope="module")
def prob():
    from repro.models import Model
    model = Model(TINY)
    params = model.init(jax.random.key(0))
    loss, per_example, _ = make_task_fns(model, SPEC)
    space = random_mask(params, density=1e-2, seed=0, balanced=False)
    gp = jnp.full((space.n,), 0.01, jnp.float32)
    return dict(params=params, loss=loss, per_example=per_example,
                space=space, gp=gp)


def mk_server(prob, n_clients=6, T=2, frac=1.0, quantize="none",
              weighted=False, plan=None, sampler=None, codec=None):
    fl = FLConfig(n_clients=n_clients, local_steps=T, batch_size=2,
                  zo_backend="ref", sample_frac=frac, quantize=quantize,
                  sample_weighted=weighted)
    clients = [Client(i, sample_dataset(SPEC, 8, seed=i), 2)
               for i in range(n_clients)]
    return FederatedZO(prob["loss"], prob["params"], prob["space"], fl,
                       clients, plan=plan, sampler=sampler, codec=codec)


def flat(tree):
    return np.concatenate([np.asarray(x, np.float32).ravel()
                           for x in jax.tree.leaves(tree)])


def assert_servers_equal(a, b):
    assert np.array_equal(flat(a.params), flat(b.params))
    assert (a.comm.up_bytes, a.comm.down_bytes) == \
        (b.comm.up_bytes, b.comm.down_bytes)
    assert a.round == b.round
    assert [c.ptr for c in a.clients] == [c.ptr for c in b.clients]
    assert a.early_stopped == b.early_stopped
    for cid in a.gradip_log:
        ea, eb = a.gradip_log[cid], b.gradip_log[cid]
        assert len(ea) == len(eb)
        for u, v in zip(ea, eb):
            assert (u is None) == (v is None)
            if u is not None:
                assert np.array_equal(u, v)


# -- ClientSampler -----------------------------------------------------------

def test_sampler_deterministic_and_well_formed():
    a = ClientSampler(range(20), frac=0.25, seed=3)
    b = ClientSampler(range(20), frac=0.25, seed=3)
    seen = set()
    for r in range(30):
        ca, cb = a.cohort(r), b.cohort(r)
        assert ca == cb  # same seed => same draws, round by round
        assert len(ca) == 5 == len(set(ca))
        assert ca == tuple(sorted(ca))
        assert set(ca) <= set(range(20))
        seen |= set(ca)
    assert seen == set(range(20))  # uniform draws cover the fleet
    assert ClientSampler(range(20), frac=0.25, seed=4).cohort() != \
        a.__class__(range(20), frac=0.25, seed=3).cohort()


def test_sampler_lockstep_enforced():
    s = ClientSampler(range(8), frac=0.5, seed=0)
    s.cohort(0)
    with pytest.raises(ValueError, match="out-of-order"):
        s.cohort(0)  # re-draw of a consumed round
    with pytest.raises(ValueError, match="out-of-order"):
        s.cohort(5)  # skipping ahead
    s.cohort(1)  # in-order continues fine
    s.cohort()   # rnd=None skips the check (manual driving)


def test_sampler_cohort_size_and_validation():
    assert ClientSampler(range(10), frac=1.0).m == 10
    assert ClientSampler(range(10), frac=0.04).m == 1  # floor at 1
    assert ClientSampler(range(10), m=3).m == 3
    with pytest.raises(ValueError, match="frac"):
        ClientSampler(range(4), frac=0.0)
    with pytest.raises(ValueError, match="cohort size"):
        ClientSampler(range(4), m=5)
    with pytest.raises(ValueError, match="duplicate"):
        ClientSampler([1, 1, 2], m=1)
    with pytest.raises(ValueError, match="need frac or m"):
        ClientSampler(range(4))


def test_sampler_weighted_draws():
    w = [0.0] * 6 + [1.0] * 6
    s = ClientSampler(range(12), m=3, weights=w, seed=1)
    assert s.weighted
    for r in range(20):
        assert set(s.cohort(r)) <= set(range(6, 12))  # zero weight => never
    with pytest.raises(ValueError, match="positive"):
        ClientSampler(range(4), m=3, weights=[1, 0, 0, 0])
    with pytest.raises(ValueError, match="shape"):
        ClientSampler(range(4), m=2, weights=[1, 1])


def test_sampler_state_roundtrip_bitexact():
    """Restoring a mid-stream state_dict re-draws the identical cohort
    sequence — the sampled analogue of the seed-ladder resume."""
    ref = ClientSampler(range(32), frac=0.25, seed=9)
    ref_draws = [ref.cohort(r) for r in range(10)]
    src = ClientSampler(range(32), frac=0.25, seed=9)
    for r in range(4):
        src.cohort(r)
    snap = src.state_dict()
    fresh = ClientSampler(range(32), frac=0.25, seed=9)
    fresh.load_state(snap)
    assert [fresh.cohort(r) for r in range(4, 10)] == ref_draws[4:]
    other = ClientSampler(range(16), frac=0.5, seed=9)
    with pytest.raises(ValueError, match="mismatch"):
        other.load_state(snap)


# -- sampled rounds ----------------------------------------------------------

def test_sampled_round_semantics(prob):
    """Only the cohort runs: bytes, data pointers, and GradIP entries for
    everyone else stay untouched, with explicit None gaps in the log."""
    srv = mk_server(prob, n_clients=6, frac=0.5)
    assert srv.sampler is not None and srv.sampler.m == 3
    T = srv.fl.local_steps
    for r in range(4):
        before = {c.cid: c.ptr for c in srv.clients}
        up0, down0 = srv.comm.up_bytes, srv.comm.down_bytes
        gs = srv.run_round(gp_vec=prob["gp"])
        cohort = srv.last_round_info["cohort"]
        assert sorted(gs) == cohort and len(cohort) == 3
        assert srv.last_round_info["n_unsampled"] == 3
        # traffic: exactly m encoded uploads + m downlinks
        assert srv.comm.up_bytes - up0 == \
            sum(srv.codec.nbytes(np.asarray(gs[c]).size) for c in cohort)
        assert srv.comm.down_bytes - down0 == 3 * srv._down_bytes(T)
        for c in srv.clients:
            gap = srv.gradip_log[c.cid][-1]
            if c.cid in cohort:
                # ptr advances (mod the client's data size)
                assert gap is not None and c.ptr != before[c.cid]
            else:
                assert gap is None and c.ptr == before[c.cid]
    # the log renders as a gap-aware matrix aligned with participation
    mat, present = gradip_matrix(srv.gradip_log[0], T=T)
    assert mat.shape == (4, T)
    for r in range(4):
        assert present[r] == (not np.isnan(mat[r]).all())


def test_unsampled_round_gradip_gap_alignment(prob):
    """gradip_matrix's present mask reproduces each client's sampled
    rounds exactly."""
    srv = mk_server(prob, n_clients=6, frac=0.5)
    cohorts = []
    for r in range(5):
        srv.run_round(gp_vec=prob["gp"])
        cohorts.append(set(srv.last_round_info["cohort"]))
    for c in srv.clients:
        _, present = gradip_matrix(srv.gradip_log[c.cid],
                                   T=srv.fl.local_steps)
        assert list(present) == [c.cid in coh for coh in cohorts]


def test_weighted_sampling_prefers_data_rich_clients(prob):
    srv = mk_server(prob, n_clients=6, frac=0.5, weighted=True)
    assert srv.sampler.weighted


def test_faults_restrict_to_cohort(prob):
    """A fault schedule drawn over the full fleet composes with any
    participation fraction: events outside the cohort are no-ops."""
    rf = RoundFaults(drops=frozenset({0, 1, 2, 3}), late={4: 1, 5: 2})
    r = rf.restrict({1, 4})
    assert r.drops == {1} and r.late == {4: 1} and not r.kill
    assert RoundFaults().restrict({0}).empty
    kill = RoundFaults(kill=True).restrict(set())
    assert kill.kill  # server-side preemption ignores the cohort

    # through the server: a drop aimed at an unsampled client changes
    # nothing vs the fault-free sampled round
    clean = mk_server(prob, frac=0.5)
    clean.run_round(gp_vec=prob["gp"])
    outside = [c.cid for c in clean.clients
               if c.cid not in clean.last_round_info["cohort"]]
    faulty = mk_server(prob, frac=0.5)
    faulty.run_round(gp_vec=prob["gp"],
                     faults=RoundFaults(drops=frozenset(outside)))
    assert_servers_equal(clean, faulty)
    assert faulty.last_round_info["drops"] == []


def test_sampled_round_with_in_cohort_faults(prob):
    """Drops/stragglers inside the cohort follow the usual fault
    bookkeeping while unsampled clients keep plain gaps."""
    srv = mk_server(prob, n_clients=6, frac=0.5)
    fp = FaultPlan(6, 8, drop_rate=0.4, late_rate=0.3, max_staleness=2,
                   seed=2)
    for r in range(8):
        srv.run_round(gp_vec=prob["gp"],
                      faults=fp.round_faults(srv.round))
        info = srv.last_round_info
        assert set(info["drops"]) <= set(info["cohort"])
        assert set(info["late"]) <= set(info["cohort"])


# -- bit-parity: frac=1.0 + identity codec == the dense round ---------------

def test_full_participation_identity_codec_bit_parity(prob):
    """An explicit full-fleet sampler + explicit IdentityCodec reproduce
    the default dense round bit-exactly — params, GradIP, CommLog."""
    dense = mk_server(prob, n_clients=4)
    assert dense.sampler is None and dense.codec.spec == "none"
    fleet = mk_server(
        prob, n_clients=4,
        sampler=ClientSampler(range(4), frac=1.0, seed=0),
        codec=IdentityCodec())
    assert fleet.sampler.m == 4
    for _ in range(3):
        dense.run_round(gp_vec=prob["gp"])
        fleet.run_round(gp_vec=prob["gp"])
    assert_servers_equal(dense, fleet)
    assert fleet.last_round_info["cohort"] == [0, 1, 2, 3]
    assert fleet.last_round_info["n_unsampled"] == 0


def test_full_participation_bit_parity_sharded(prob):
    """Same parity under a 1x1 FLShardPlan: the sampled/codec plumbing
    is mesh-neutral (DESIGN.md §9 composed with §12)."""
    from repro.sharding.fl import make_fl_plan
    plan = make_fl_plan(spec="1x1")
    dense = mk_server(prob, n_clients=4)
    fleet = mk_server(
        prob, n_clients=4, plan=plan,
        sampler=ClientSampler(range(4), frac=1.0, seed=0),
        codec=IdentityCodec())
    for _ in range(2):
        dense.run_round(gp_vec=prob["gp"])
        fleet.run_round(gp_vec=prob["gp"])
    assert_servers_equal(dense, fleet)


# -- quantized uplink --------------------------------------------------------

def test_quantized_round_exact_replay(prob):
    """The round's returned scalars are on the wire grid: the server's
    deterministic re-encode is lossless, and the virtual path
    reconstructed from the *wire payload alone* bit-matches the dense
    reconstruction from the decoded scalars."""
    srv = mk_server(prob, n_clients=3, quantize="int8")
    T = srv.fl.local_steps
    gs = srv.run_round()
    assert srv.codec.spec == "int8"
    for cid, g in gs.items():
        w = srv.codec.encode(g)  # nearest re-encode of on-grid values
        np.testing.assert_array_equal(srv.codec.decode(w), g)
        keys = round_keys(srv.fl.seed, 0, T)
        via_wire = VP.reconstruct_from_wire(prob["space"], keys, w,
                                            srv.codec, srv.fl.lr)
        direct = VP.reconstruct_delta(prob["space"], keys, jnp.asarray(g),
                                      srv.fl.lr)
        np.testing.assert_array_equal(np.asarray(via_wire),
                                      np.asarray(direct))


def test_quantized_uplink_bytes_and_effect(prob):
    """int8 halves the f32 uplink (1 code + 1 exponent byte per scalar
    at chunk=1) and actually changes the trajectory; downlink is
    untouched."""
    T = 2
    dense = mk_server(prob, n_clients=3, T=T)
    quant = mk_server(prob, n_clients=3, T=T, quantize="int8")
    dense.run_round()
    quant.run_round()
    assert dense.comm.up_bytes == 3 * 4 * T
    assert quant.comm.up_bytes == 3 * 2 * T
    assert dense.comm.down_bytes == quant.comm.down_bytes
    assert not np.array_equal(flat(dense.params), flat(quant.params))


def test_quantized_loop_matches_codec_grid(prob):
    """The compiled T=1 burst with a QuantSpec emits per-step scalars
    that the host codec reproduces bit-exactly (jax<->host grid parity
    inside the real train loop)."""
    codec = IntCodec(bits=8, stochastic=True)
    loop = make_fl_train_loop(prob["per_example"], prob["space"], eps=1e-3,
                              lr=1e-2, n_clients=4, n_steps=3,
                              backend="ref", quantize=codec.jax_spec())
    batch = sample_dataset(SPEC, 4 * 2 * 3, seed=0)
    batches = {k: jnp.asarray(v).reshape(3, 4 * 2, *np.shape(v)[1:])
               for k, v in batch.items()}
    _, gs, _ = jax.jit(loop)(prob["params"], jax.random.key(1), batches)
    gs = np.asarray(gs)
    np.testing.assert_array_equal(codec.decode(codec.encode(gs)), gs)


def test_loop_report_masks_all_ones_is_dense(prob):
    """report_masks as a runtime operand: all-ones masks match the
    maskless loop bitwise (one compiled program for every cohort)."""
    loop = make_fl_train_loop(prob["per_example"], prob["space"], eps=1e-3,
                              lr=1e-2, n_clients=4, n_steps=2,
                              backend="ref")
    batch = sample_dataset(SPEC, 4 * 2 * 2, seed=0)
    batches = {k: jnp.asarray(v).reshape(2, 4 * 2, *np.shape(v)[1:])
               for k, v in batch.items()}
    jloop = jax.jit(loop)
    p0, g0, _ = jloop(prob["params"], jax.random.key(1), batches)
    p1, g1, _ = jloop(prob["params"], jax.random.key(1), batches,
                      jnp.ones((2, 4), jnp.float32))
    np.testing.assert_array_equal(flat(p0), flat(p1))
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))
    # masking out half the clients changes the aggregate
    p2, _, _ = jloop(prob["params"], jax.random.key(1), batches,
                     jnp.asarray([[1, 1, 0, 0], [1, 1, 0, 0]], jnp.float32))
    assert not np.array_equal(flat(p0), flat(p2))


# -- checkpoint/resume under sampling + quantization -------------------------

def test_sampled_quantized_resume_bitexact(prob, tmp_path):
    """Save at round 2 of a sampled+quantized run, restore into a fresh
    server, continue: bit-identical to the uninterrupted run — including
    the sampler's re-drawn cohorts (RNG state restore)."""
    path = str(tmp_path / "ckpt.msgpack")
    ref = mk_server(prob, frac=0.5, quantize="int8")
    cohorts_ref = []
    for _ in range(5):
        ref.run_round(gp_vec=prob["gp"])
        cohorts_ref.append(ref.last_round_info["cohort"])
    donor = mk_server(prob, frac=0.5, quantize="int8")
    for _ in range(2):
        donor.run_round(gp_vec=prob["gp"])
    donor.save_checkpoint(path)
    fresh = mk_server(prob, frac=0.5, quantize="int8")
    meta = fresh.load_checkpoint(path)
    assert meta["round"] == 2 and meta["sampler"] is not None
    cohorts_resumed = []
    for _ in range(3):
        fresh.run_round(gp_vec=prob["gp"])
        cohorts_resumed.append(fresh.last_round_info["cohort"])
    assert cohorts_resumed == cohorts_ref[2:]
    assert_servers_equal(ref, fresh)
    assert fresh.sampler.state_dict() == ref.sampler.state_dict()


def test_sampler_presence_mismatch_refused(prob, tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    donor = mk_server(prob, frac=0.5)
    donor.run_round()
    donor.save_checkpoint(path)
    dense = mk_server(prob)  # no sampler: config fingerprint differs
    with pytest.raises(CheckpointError):
        dense.load_checkpoint(path)


# -- O(seeds + scalars) server state -----------------------------------------

def test_server_state_o1_in_fleet_size(prob):
    """Growing K grows only the per-client scalar bookkeeping (a few
    bytes per client), never the model-sized state — the argument that
    lets one server host thousands of ZO clients."""
    small = mk_server(prob, n_clients=4, frac=0.5)
    big = mk_server(prob, n_clients=32, frac=0.5)
    for _ in range(2):
        small.run_round(gp_vec=prob["gp"])
        big.run_round(gp_vec=prob["gp"])
    a, b = server_state_sizes(small), server_state_sizes(big)
    assert a["model_state_bytes"] == b["model_state_bytes"]
    # per-client bookkeeping stays tiny: pointers + a few logged scalars
    per_client = b["per_client_state_bytes"] / b["n_clients"]
    assert per_client < 1024
