"""Decode-attention backend dispatch: the Pallas flash-decode kernel vs the
grouped jnp reference at model-shaped caches (per-row lengths, softcaps,
non-block-multiple capacities), and the layer-level route selection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.tiny import TINY
from repro.kernels import ops, ref
from repro.models import Model, layers as L
from repro.models.transformer import ShardCtx


@pytest.mark.parametrize("B,KVH,G,dh,S", [
    (1, 2, 2, 32, 100),      # non-block-multiple cache
    (3, 2, 4, 64, 257),      # prime-ish capacity, per-row lengths
    (2, 4, 1, 128, 96),      # MQA-free layout, small cache
])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_flash_decode_model_shapes(B, KVH, G, dh, S, softcap):
    key = jax.random.key(B * S)
    q = jax.random.normal(key, (B, KVH, G, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KVH, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KVH, dh))
    lengths = jnp.asarray(np.linspace(1, S, B).astype(np.int32))
    out = ops.flash_decode(q, k, v, lengths, block_s=64, softcap=softcap)
    want = ref.decode_attention_ref(q, k, v, lengths, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_flash_decode_scalar_length_compat():
    key = jax.random.key(7)
    q = jax.random.normal(key, (2, 2, 2, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 80, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 80, 2, 32))
    out = ops.flash_decode(q, k, v, 50, block_s=32)
    want = ref.decode_attention_ref(q, k, v, 50)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def _layer_params(cfg, key):
    model = Model(cfg)
    params = model.init(key)
    stack = params["stack"]
    for k in stack:
        if "wq" in stack[k]:
            return jax.tree.map(lambda l: l[0], stack[k])
    raise AssertionError("no attention layer")


@pytest.mark.parametrize("name,local", [
    ("qwen2-7b", False),       # GQA + qkv biases
    ("gemma2-27b", False),     # softcaps
    ("gemma2-27b", True),      # rolling sliding-window buffer
])
def test_decode_self_attention_pallas_vs_ref(name, local):
    """Layer-level parity at a model-shaped cache with per-row positions,
    including a non-block-multiple capacity."""
    cfg = get_config(name).reduced()
    lp = _layer_params(cfg, jax.random.key(0))
    B, W = 3, 36  # not a multiple of any kernel block
    if local and cfg.sliding_window:
        W = min(W, cfg.sliding_window)
    hd = cfg.resolved_head_dim
    key = jax.random.key(1)
    x1 = jax.random.normal(key, (B, 1, cfg.d_model))
    ck = jax.random.normal(jax.random.fold_in(key, 1),
                           (B, W, cfg.n_kv_heads, hd))
    cv = jax.random.normal(jax.random.fold_in(key, 2),
                           (B, W, cfg.n_kv_heads, hd))
    pos = jnp.asarray([2, W - 1, W // 2], jnp.int32)
    outs = {}
    for backend in ("pallas", "ref"):
        ctx = ShardCtx(decode_backend=backend)
        out, nk, nv = L.decode_self_attention(x1, lp, cfg, ck, cv, pos,
                                              local=local, ctx=ctx)
        outs[backend] = (np.asarray(out), np.asarray(nk), np.asarray(nv))
    np.testing.assert_allclose(outs["pallas"][0], outs["ref"][0], atol=3e-5)
    np.testing.assert_array_equal(outs["pallas"][1], outs["ref"][1])
    np.testing.assert_array_equal(outs["pallas"][2], outs["ref"][2])


def test_resolve_decode_backend():
    assert L.resolve_decode_backend("pallas", TINY) == "pallas"
    assert L.resolve_decode_backend("ref", TINY) == "ref"
    # auto off-mesh prefers the kernel (interpret mode on CPU)
    assert L.resolve_decode_backend("auto", TINY) == "pallas"
    assert L.resolve_decode_backend(None, TINY) == "pallas"
    with pytest.raises(ValueError):
        L.resolve_decode_backend("cuda", TINY)


def test_auto_falls_back_on_mesh():
    """Sharded ctx: the jnp path carries the GSPMD constraints, so auto
    must not pick the kernel."""
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("model",))
    ctx = ShardCtx(mesh=mesh, batch_axes=(), model_axis="model")
    assert L.resolve_decode_backend("auto", TINY, ctx) == "ref"


def test_default_ctx_routes_pallas():
    """backend='auto' is the default: a plain Model decode step runs the
    flash-decode kernel (asserted via the resolved route)."""
    model = Model(TINY)
    assert model.ctx.decode_backend == "auto"
    assert L.resolve_decode_backend(model.ctx.decode_backend, TINY,
                                    model.ctx) == "pallas"
