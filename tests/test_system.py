"""End-to-end behaviour tests for the paper's system (Algorithm 2/3/1).

These exercise the full FederatedZO server loop on the tiny model:
learning progress, virtual-path/client equivalence at the server level,
communication accounting, VP calibration + early stopping, and the
high-frequency fl_train_step production path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.configs.tiny import TINY
from repro.core import (Client, FederatedZO, pretrain_gradient_vec,
                        random_mask, sensitivity_mask)
from repro.core.fl_step import make_fl_train_step
from repro.data.corpus import pretrain_batches
from repro.data.partition import (dirichlet_partition, single_label_partition,
                                  subset)
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.models import Model

SPEC = TaskSpec()


@pytest.fixture(scope="module")
def problem():
    model = Model(TINY)
    params = model.init(jax.random.key(0))
    loss, per_example, evaluate = make_task_fns(model, SPEC)
    train = sample_dataset(SPEC, 512, seed=1)
    ev = sample_dataset(SPEC, 256, seed=2)
    eval_batch = {k: jnp.asarray(v) for k, v in ev.items()}
    pre = pretrain_batches(SPEC, n_batches=4, batch_size=16)
    return dict(model=model, params=params, loss=loss,
                per_example=per_example, evaluate=evaluate, train=train,
                eval_batch=eval_batch, pre=pre)


def _clients(problem, n=4, partition="dirichlet", bs=16):
    labels = problem["train"]["label"]
    parts = (dirichlet_partition(labels, n, 0.5, seed=0)
             if partition == "dirichlet"
             else single_label_partition(labels, n, seed=0))
    return [Client(k, subset(problem["train"], p), bs)
            for k, p in enumerate(parts)]


def _server(problem, space, T=1, lr=5e-2, n=4, **kw):
    fl = FLConfig(n_clients=n, local_steps=T, lr=lr, eps=1e-3, **kw)
    return FederatedZO(problem["loss"], problem["params"], space, fl,
                       _clients(problem, n), eval_fn=problem["evaluate"])


def test_meerkat_rounds_reduce_eval_loss(problem):
    space = sensitivity_mask(
        lambda p, b: problem["model"].loss(p, b), problem["params"],
        problem["pre"], density=1e-2)
    srv = _server(problem, space, T=1, lr=5e-2)
    m0 = problem["evaluate"](problem["params"], problem["eval_batch"])
    srv.run(60)
    m1 = problem["evaluate"](srv.params, problem["eval_batch"])
    assert float(m1["loss"]) < float(m0["loss"])
    assert float(m1["acc"]) > float(m0["acc"])


def test_params_only_change_on_masked_coords(problem):
    """MEERKAT's updates are restricted to the static sparse subset."""
    space = random_mask(problem["params"], density=5e-3, seed=3,
                        balanced=False)
    srv = _server(problem, space, T=2, lr=1e-2)
    srv.run(2)
    diff = jax.tree.map(lambda a, b: np.asarray(a - b), srv.params,
                        problem["params"])
    changed = int(sum((d != 0).sum() for d in jax.tree.leaves(diff)))
    assert changed <= space.n  # never touches unmasked coordinates


def test_comm_log_scalar_uploads(problem):
    """Upload is exactly 4*T bytes per client per round (scalars only)."""
    space = random_mask(problem["params"], density=1e-2, seed=0)
    T, rounds, n = 3, 5, 4
    srv = _server(problem, space, T=T)
    srv.run(rounds)
    assert srv.comm.up_bytes == 4 * T * rounds * n


def test_high_freq_download_is_scalars(problem):
    space = random_mask(problem["params"], density=1e-2, seed=0)
    srv = _server(problem, space, T=1)  # high_freq auto-on at T=1
    srv.run(4)
    # down = aggregated scalar + next seed per round per client
    assert srv.comm.down_bytes == (4 * 1 + 8) * 4 * 4
    srv_lo = _server(problem, space, T=2)
    srv_lo.run(1)
    assert srv_lo.comm.down_bytes == 4 * space.n * 4  # sparse refresh


def test_vp_calibration_flags_single_label_clients(problem):
    """VPCS (Alg. 1) detects the single-label extreme clients."""
    space = sensitivity_mask(
        lambda p, b: problem["model"].loss(p, b), problem["params"],
        problem["pre"], density=5e-2)
    labels = problem["train"]["label"]
    parts = (dirichlet_partition(labels, 3, 5.0, seed=0)
             + single_label_partition(labels, 1, seed=1))
    clients = [Client(k, subset(problem["train"], p), 32)
               for k, p in enumerate(parts)]
    fl = FLConfig(n_clients=4, local_steps=5, lr=5e-2, eps=1e-3,
                  vp_calibration_steps=200, vp_init_steps=40,
                  vp_later_steps=40, vp_sigma=0.25, vp_sigma_relative=True,
                  vp_rho_later=3.0, vp_rho_quie=0.6)
    srv = FederatedZO(problem["loss"], problem["params"], space, fl, clients,
                      eval_fn=problem["evaluate"])
    gp = pretrain_gradient_vec(lambda p, b: problem["model"].loss(p, b),
                               problem["params"], space, problem["pre"])
    results, flagged, trajs = srv.calibrate_vp(gp)
    assert 3 in flagged, [r.rho_later for r in results]
    # flagged clients run T=1 afterwards
    srv.run_round()
    assert srv._client_T(3) == 1 and srv._client_T(0) in (1, 5)


def test_early_stopped_client_data_pointer_advances(problem):
    """Paper §2.5: early-stopped clients resume from the data pointer."""
    c = _clients(problem, n=1)[0]
    p0 = c.ptr
    c.next_batches(1)
    assert c.ptr == (p0 + c.batch_size) % c.n


def test_fl_train_step_matches_manual_t1_round(problem):
    """The production T=1 fused step (what the dry-run lowers) computes the
    same update as the simulation server's T=1 round."""
    space = random_mask(problem["params"], density=1e-2, seed=5,
                        balanced=False)
    n_clients, bs = 4, 8
    eps, lr = 1e-3, 1e-2
    step = make_fl_train_step(problem["per_example"], space, eps=eps, lr=lr,
                              n_clients=n_clients)
    data = sample_dataset(SPEC, n_clients * bs, seed=9)
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    key = jax.random.key(42)
    new_params, g_clients, metrics = jax.jit(step)(problem["params"], key,
                                                   batch)
    # manual: per-client projected grads on the same shared z
    z = space.sample_z(key)
    wp = space.add(problem["params"], eps * z)
    wm = space.add(problem["params"], -eps * z)
    lp = problem["per_example"](wp, batch).reshape(n_clients, bs).mean(-1)
    lm = problem["per_example"](wm, batch).reshape(n_clients, bs).mean(-1)
    g_manual = (lp - lm) / (2 * eps)
    np.testing.assert_allclose(np.asarray(g_clients), np.asarray(g_manual),
                               rtol=1e-3, atol=1e-5)
    want = space.add(problem["params"], -lr * float(g_manual.mean()) * z)
    got_flat = space.slice(new_params)
    want_flat = space.slice(want)
    np.testing.assert_allclose(np.asarray(got_flat), np.asarray(want_flat),
                               rtol=1e-3, atol=1e-5)


def test_seed_reuse_across_methods_is_identical(problem):
    """Same seed => identical client batches and perturbations => two servers
    with the same space produce bit-identical global models."""
    space = random_mask(problem["params"], density=1e-2, seed=0)
    a = _server(problem, space, T=2, lr=1e-2, seed=7)
    b = _server(problem, space, T=2, lr=1e-2, seed=7)
    a.run(2)
    b.run(2)
    for la, lb in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
