"""The static analyzer (DESIGN.md §10): every rule flags its seeded
known-bad fixture and passes its known-good twin, the registry covers the
hot paths the perf story rests on, the report schema is stable, and the
CLI's exit-code contract holds."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (ALL_RULES, FIXTURES, HOT_PATHS,
                            check_no_dense_intermediates, liveness_peak_bytes,
                            max_square_dims, run_analysis, run_program,
                            write_report)
from repro.analysis.core import SCHEMA_VERSION
from repro.analysis.registry import programs_by_name

REPO = os.path.join(os.path.dirname(__file__), "..")


def _errors(rows):
    return [f for r in rows for f in r["findings"]
            if f["severity"] == "error"]


# ------------------------------------------------------ fixture matrix ------
@pytest.mark.parametrize("rule", ALL_RULES, ids=lambda r: r.name)
def test_rule_flags_bad_fixture(rule):
    fx = FIXTURES[rule.name]
    assert fx["bad"], f"{rule.name} has no known-bad fixture"
    for prog in fx["bad"]:
        errs = _errors(run_program(prog, [rule]))
        assert errs, f"{rule.name} missed its bad fixture {prog.name}"


@pytest.mark.parametrize("rule", ALL_RULES, ids=lambda r: r.name)
def test_rule_passes_good_fixture(rule):
    fx = FIXTURES[rule.name]
    assert fx["good"], f"{rule.name} has no known-good fixture"
    for prog in fx["good"]:
        errs = _errors(run_program(prog, [rule]))
        assert not errs, (rule.name, prog.name, errs)


# ------------------------------------------------------------ registry ------
def test_registry_covers_hot_paths():
    names = {p.name for p in HOT_PATHS}
    assert {"zo_train_loop", "fl_round", "fl_round_sharded", "prefill",
            "decode_burst", "first_order"} <= names
    for p in HOT_PATHS:
        assert p.description and callable(p.build)


def test_registry_selection():
    sel = programs_by_name(["prefill", "zo_train_loop"])
    assert [p.name for p in sel] == ["prefill", "zo_train_loop"]
    with pytest.raises(KeyError):
        programs_by_name(["no_such_program"])


def test_sharded_round_skips_without_devices():
    # tests run single-device (conftest): the 2x2-mesh program must skip
    # cleanly, not crash, and skipped rows count as ok
    prog = programs_by_name(["fl_round_sharded"])[0]
    if jax.device_count() >= 4:
        pytest.skip("multi-device process; skip path not reachable")
    rows = run_program(prog, list(ALL_RULES))
    assert rows and all(r["ok"] and r.get("skipped") for r in rows)


# ------------------------------------------------------- report schema ------
def test_report_schema_and_write(tmp_path):
    rule = next(r for r in ALL_RULES if r.name == "host-sync")
    progs = FIXTURES["host-sync"]["bad"] + FIXTURES["host-sync"]["good"]
    report = run_analysis(progs, [rule])
    assert report["schema_version"] == SCHEMA_VERSION
    for key in ("jax_version", "n_devices", "programs", "rules", "results",
                "violations", "ok"):
        assert key in report, key
    assert report["violations"] > 0 and report["ok"] is False
    for row in report["results"]:
        assert {"program", "rule", "ok", "findings"} <= set(row)
        for f in row["findings"]:
            assert {"rule", "program", "message", "severity"} <= set(f)
    path = write_report(report, str(tmp_path / "sub" / "ANALYSIS.json"))
    assert json.load(open(path)) == json.loads(json.dumps(report))


# ------------------------------------------------- standalone predicates ----
def test_dense_predicate():
    S = 64
    bad = jax.make_jaxpr(lambda q, k: jnp.einsum("sd,td->st", q, k))(
        jnp.ones((S, 8)), jnp.ones((S, 8)))
    good = jax.make_jaxpr(lambda q, k: (q * k).sum(-1))(
        jnp.ones((S, 8)), jnp.ones((S, 8)))
    offenders = check_no_dense_intermediates(bad, S)
    assert offenders and offenders[0]["shape"] == [S, S]
    assert not check_no_dense_intermediates(good, S)
    # back-compat surface (repro.utils re-export still works)
    from repro.utils import max_square_dims as legacy
    assert legacy is max_square_dims
    assert max_square_dims(bad, S) >= 2 > max_square_dims(good, S)


def test_liveness_peak_tracks_buffer_size():
    def f(x):
        return jnp.outer(x, x).sum()

    small = liveness_peak_bytes(jax.make_jaxpr(f)(jnp.ones(128)))
    big = liveness_peak_bytes(jax.make_jaxpr(f)(jnp.ones(1024)))
    assert big >= 1024 * 1024 * 4        # the [1024, 1024] f32 outer product
    assert big > small


# ------------------------------------------------------------ CLI ----------
def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)


def test_cli_list_exit_zero():
    r = _cli("--list")
    assert r.returncode == 0, r.stderr
    for name in ("zo_train_loop", "dense-materialization", "comm-budget"):
        assert name in r.stdout


def test_cli_fixture_mode_fires_nonzero():
    r = _cli("--fixture", "host-sync")
    assert r.returncode == 1, (r.stdout, r.stderr)
    assert "violation" in r.stdout


def test_cli_unknown_program_is_usage_error():
    r = _cli("--programs", "no_such_program")
    assert r.returncode == 2
    assert "no_such_program" in r.stderr
