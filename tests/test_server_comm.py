"""FederatedZO accounting with the multi-direction estimator: clients
upload T*K scalars (not T), and GradIP trajectories reduce the [T, K] gs
to one scalar per local step instead of crashing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.tiny import TINY
from repro.core import random_mask
from repro.core.server import Client, FederatedZO, _per_step
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.models import Model


def _setup(n_dirs: int, T: int = 2, n_clients: int = 2):
    spec = TaskSpec(vocab=min(TINY.vocab, 512))
    model = Model(TINY)
    params = model.init(jax.random.key(0))
    loss, _, _ = make_task_fns(model, spec)
    space = random_mask(params, density=1e-2, seed=0, balanced=False)
    fl = FLConfig(n_clients=n_clients, local_steps=T, batch_size=2,
                  n_dirs=n_dirs)
    clients = [Client(i, sample_dataset(spec, 8, seed=i), 2)
               for i in range(n_clients)]
    return FederatedZO(loss, params, space, fl, clients), space


def test_per_step_reduction():
    np.testing.assert_allclose(_per_step(np.arange(3.0)), np.arange(3.0))
    g = np.arange(6.0).reshape(2, 3)
    np.testing.assert_allclose(_per_step(g), g.mean(axis=1))


def test_multi_dir_round_bytes_and_gradip():
    srv, space = _setup(n_dirs=3, T=2, n_clients=2)
    gp = jnp.full((space.n,), 0.01, jnp.float32)
    gs = srv.run_round(gp_vec=gp)
    assert gs[0].shape == (2, 3)  # [T, K] scalars uploaded
    # up bytes count every scalar: 2 clients * T*K * 4 bytes
    assert srv.comm.up_bytes == 2 * 2 * 3 * 4
    for cid in (0, 1):
        (ips,) = srv.gradip_log[cid]
        assert ips.shape == (2,)  # one GradIP per local step
        assert np.isfinite(ips).all()


def test_multi_dir_calibration():
    srv, space = _setup(n_dirs=2, T=2)
    gp = jnp.full((space.n,), 0.01, jnp.float32)
    results, flagged, trajs = srv.calibrate_vp(gp, T_cali=2)
    assert len(trajs) == 2
    assert all(t.shape == (2,) and np.isfinite(t).all() for t in trajs)


def test_single_dir_bytes_unchanged():
    srv, space = _setup(n_dirs=1, T=2, n_clients=2)
    srv.run_round()
    assert srv.comm.up_bytes == 2 * 2 * 4  # 2 clients * T scalars * 4 bytes


def test_high_freq_down_bytes_count_directions():
    """High-frequency broadcast must carry all T*K per-direction scalars:
    the virtual-path replay needs every g_tk, not one scalar per step."""
    srv, _ = _setup(n_dirs=4, T=1, n_clients=2)  # T=1 -> high_freq on
    assert srv.high_freq
    srv.run_round()
    assert srv.comm.down_bytes == 2 * (4 * 1 * 4 + 8)
    assert srv.comm.up_bytes == 2 * 1 * 4 * 4
