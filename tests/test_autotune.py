"""kernels/autotune: table I/O, cached-pick determinism, resolver and
ops wiring, and the CLI's --require-cached determinism gate."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.tiny import TINY
from repro.kernels import autotune as AT
from repro.models import layers as L

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True)
def _table_dir(monkeypatch, tmp_path):
    d = str(tmp_path / "autotune")
    monkeypatch.setenv("REPRO_AUTOTUNE_DIR", d)
    AT.clear_cache()
    yield d
    AT.clear_cache()


def _stub_measure(monkeypatch, route="pallas", bq=64, bk=32):
    calls = []

    def fake(op, S, head_dim, G, **kw):
        calls.append((op, S, head_dim, G))
        return dict(route=route, block_q=bq, block_k=bk,
                    best_pallas_ms=1.0, online_ms=2.0,
                    pallas_ms={f"{bq}x{bk}": 1.0}, reps=1, batch=1,
                    kv_heads=1)

    monkeypatch.setattr(AT, "measure", fake)
    return calls


def test_ensure_writes_then_reuses(monkeypatch, _table_dir):
    calls = _stub_measure(monkeypatch)
    e1, measured1 = AT.ensure("fwd", 256, 16, 2)
    assert measured1 and calls == [("fwd", 256, 16, 2)]
    # cached entry is authoritative: no re-measure, identical pick
    e2, measured2 = AT.ensure("fwd", 256, 16, 2)
    assert not measured2 and e2 == e1 and len(calls) == 1
    # a fresh process (cache cleared) rereads the same pick from disk
    AT.clear_cache()
    e3, measured3 = AT.ensure("fwd", 256, 16, 2)
    assert not measured3 and e3 == e1 and len(calls) == 1
    # the on-disk table holds the platform-scoped key
    tab = json.load(open(AT.table_path()))
    assert AT.key_for("fwd", 256, 16, 2) in tab
    # force re-measures
    _, measured4 = AT.ensure("fwd", 256, 16, 2, force=True)
    assert measured4 and len(calls) == 2


def test_lookup_helpers(monkeypatch):
    _stub_measure(monkeypatch, route="online", bq=128, bk=64)
    AT.ensure("fwd", 1024, 16, 2)
    assert AT.fastest_route(1024, 16, 2, op="fwd") == "online"
    assert AT.fastest_route(1024, 16, 2, op="grad") is None  # exact-op key
    assert AT.fastest_route(999, 16, 2, op="fwd") is None
    # best_blocks serves the tuned blocks, falling back across ops
    assert AT.best_blocks(1024, 16, 2, op="fwd") == (128, 64)
    assert AT.best_blocks(1024, 16, 2, op="grad") == (128, 64)
    assert AT.best_blocks(999, 16, 2) is None


def test_resolver_consults_table(monkeypatch):
    """'auto' must pick the measured-fastest route for a tuned key — in
    both directions, and separately per op (fwd vs grad traces)."""
    hd, G = TINY.resolved_head_dim, TINY.n_heads // TINY.n_kv_heads
    S = 1024
    # untuned on this (interpreting) host: online fwd, pallas grad
    assert L.resolve_attn_backend("auto", TINY, S=S) == "online"
    assert L.resolve_attn_backend("auto", TINY, S=S,
                                  differentiable=True) == "pallas"
    # tuned: fwd says pallas wins, grad says online wins — auto follows
    _stub_measure(monkeypatch, route="pallas")
    AT.ensure("fwd", S, hd, G)
    _stub_measure(monkeypatch, route="online")
    AT.ensure("grad", S, hd, G)
    assert L.resolve_attn_backend("auto", TINY, S=S) == "pallas"
    assert L.resolve_attn_backend("auto", TINY, S=S,
                                  differentiable=True) == "online"
    # other keys stay on the heuristic
    assert L.resolve_attn_backend("auto", TINY, S=2048) == "online"


def test_ops_flash_attention_uses_tuned_blocks(monkeypatch):
    """ops.flash_attention launches with the table's blocks when the
    caller doesn't pin them — same numerics, tuned launch grid."""
    S, H, KV, hd = 192, 2, 1, 24   # unique shape: fresh trace guaranteed
    from repro.kernels import ops as K
    seen = []
    real = AT.best_blocks

    def spy(S_, hd_, G_, op="fwd", dirname=None):
        seen.append((S_, hd_, G_, op))
        return (96, 96)

    monkeypatch.setattr(AT, "best_blocks", spy)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, S, KV, hd)), jnp.float32)
    out = K.flash_attention(q, k, v)
    assert (S, hd, H // KV, "fwd") in seen
    monkeypatch.setattr(AT, "best_blocks", real)
    ref = K.flash_attention(q, k, v, block_q=96, block_k=96)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_measure_real_smoke():
    """One real (tiny) measurement: fields present, a sane winner."""
    e = AT.measure("fwd", 64, 8, 2, reps=1, candidates=((16, 16), (32, 32)))
    assert e["route"] in ("pallas", "online")
    assert set(e["pallas_ms"]) == {"16x16", "32x32"}
    assert e["best_pallas_ms"] > 0 and e["online_ms"] > 0
    assert (e["block_q"], e["block_k"]) in ((16, 16), (32, 32))


def test_measure_excludes_degenerate_single_tile():
    """A candidate whose score block reaches [S, S] (block_q*G >= S and
    block_k >= S) must never win: it would reintroduce the dense-sized
    buffer the no-[S,S] jaxpr walk proves absent.  With every candidate
    degenerate, measure falls back to a KV-tiled shrink."""
    e = AT.measure("fwd", 64, 8, 2, reps=1,
                   candidates=((32, 32), (64, 64)))
    assert "64x64" not in e["pallas_ms"]          # filtered out
    assert set(e["pallas_ms"]) == {"32x32"}
    e2 = AT.measure("fwd", 64, 8, 2, reps=1, candidates=((64, 64),))
    assert set(e2["pallas_ms"]) == {"64x32"}      # fallback: block_k halved


def test_cli_require_cached_gate(monkeypatch, _table_dir, capsys):
    """Two CLI runs over the same keys: the first measures and persists,
    the second is all-cached — the CI determinism gate."""
    _stub_measure(monkeypatch)
    args = ["--s-list", "64", "--head-dim", "8", "--g", "2",
            "--reps", "1", "--ops", "fwd"]
    assert AT.main(args) == 0
    # a second run must reuse every pick: --require-cached passes
    assert AT.main(args + ["--require-cached"]) == 0
    out = capsys.readouterr().out
    assert "[cached]" in out
    # --force re-measures, so the gate fails
    assert AT.main(args + ["--require-cached", "--force"]) == 1
    # --list prints the table
    assert AT.main(["--list"]) == 0
    assert "fwd|" in capsys.readouterr().out
