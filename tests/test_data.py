"""Data substrate tests: synthetic tasks, Dirichlet partitioning, corpus."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import numpy as np

from repro.data import (TaskSpec, dirichlet_partition, label_histogram,
                        pretrain_batches, sample_dataset,
                        single_label_partition, subset)


def test_dataset_shapes_and_sep():
    spec = TaskSpec(vocab=256, n_classes=4, seq_len=12)
    d = sample_dataset(spec, 100, seed=0)
    assert d["tokens"].shape == (100, 12)
    assert d["label"].shape == (100,)
    assert (d["tokens"][:, -1] == spec.sep_token).all()
    assert d["label"].min() >= 0 and d["label"].max() < 4


def test_class_conditional_distributions_differ():
    spec = TaskSpec(vocab=256, n_classes=4, seq_len=32, noise=0.0)
    d = sample_dataset(spec, 400, seed=1)
    from repro.data.synthetic import _class_vocab
    cv = _class_vocab(spec)
    for c in range(4):
        rows = d["tokens"][d["label"] == c][:, :-1]
        assert np.isin(rows, cv[c]).all()


@hypothesis.given(alpha=st.sampled_from([0.1, 0.5, 5.0]),
                  n_clients=st.integers(2, 10))
@hypothesis.settings(max_examples=10, deadline=None)
def test_dirichlet_partition_disjoint_and_complete(alpha, n_clients):
    labels = np.random.default_rng(0).integers(0, 4, size=500)
    parts = dirichlet_partition(labels, n_clients, alpha, seed=1)
    allidx = np.concatenate(parts)
    assert len(allidx) == 500
    assert len(np.unique(allidx)) == 500  # disjoint + complete


def test_dirichlet_alpha_controls_heterogeneity():
    labels = np.random.default_rng(0).integers(0, 4, size=4000)
    h_iid = label_histogram(labels, dirichlet_partition(labels, 8, 100.0,
                                                        seed=2), 4)
    h_non = label_histogram(labels, dirichlet_partition(labels, 8, 0.1,
                                                        seed=2), 4)

    def skew(h):
        p = h / np.maximum(h.sum(1, keepdims=True), 1)
        return float(np.mean(p.max(1)))

    assert skew(h_non) > skew(h_iid) + 0.15


def test_single_label_partition_is_pure():
    labels = np.random.default_rng(0).integers(0, 4, size=1000)
    parts = single_label_partition(labels, 8, seed=0)
    for k, p in enumerate(parts):
        assert len(set(labels[p])) == 1
        assert labels[p][0] == k % 4


def test_subset_and_pretrain_batches():
    spec = TaskSpec(vocab=128, n_classes=4, seq_len=8)
    d = sample_dataset(spec, 50, seed=0)
    s = subset(d, np.arange(5))
    assert s["tokens"].shape == (5, 8)
    pb = pretrain_batches(spec, 3, 4)
    assert len(pb) == 3 and pb[0]["tokens"].shape == (4, 8)
