"""Serving correctness: right-padded batched generation must reproduce
single-request generation exactly (greedy tokens), across every arch's
cache family; the continuous-batching engine must match too, admit work
into freed slots, and never re-trace at steady state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import Model
from repro.serving import ContinuousBatchingEngine, ServeEngine, generate
from repro.serving.engine import _decode_loop, _frontend_stub

LENS = [3, 7, 5]
MAX_NEW = 4


def _single_outputs(model, params, prompts, max_new, S_max):
    outs = []
    for p in prompts:
        batch = {"tokens": jnp.asarray(p)[None],
                 **_frontend_stub(model.cfg, 1)}
        outs.append(np.asarray(
            generate(model, params, batch, max_new, S_max=S_max)[0]))
    return outs


@pytest.mark.parametrize("name", list_archs())
def test_padded_batch_matches_single(name):
    """Mixed-length right-padded batch == each request generated alone
    (attn / local_attn / mamba / mlstm / slstm caches, all frontends)."""
    cfg = get_config(name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=L).astype(np.int32)
               for L in LENS]
    S_pad = 8
    extra = cfg.n_patches if cfg.frontend == "vision_stub" else 0
    S_max = S_pad + extra + MAX_NEW
    singles = _single_outputs(model, params, prompts, MAX_NEW, S_max)

    toks = np.zeros((len(LENS), S_pad), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    batch = {"tokens": jnp.asarray(toks),
             **_frontend_stub(cfg, len(LENS))}
    gen = generate(model, params, batch, MAX_NEW, S_max=S_max,
                   lengths=jnp.asarray(LENS, jnp.int32))
    for i, want in enumerate(singles):
        np.testing.assert_array_equal(np.asarray(gen[i]), want,
                                      err_msg=f"{name} row {i}")


@pytest.mark.parametrize("name", ["tiny", "qwen2-7b", "xlstm-350m"])
def test_continuous_engine_matches_single(name):
    """More requests than slots, heterogeneous lengths + budgets: the
    slot engine's outputs equal single-request generation, requests admit
    into freed slots, and finished slots exit early."""
    if name == "tiny":
        from repro.configs.tiny import TINY
        cfg = TINY
    else:
        cfg = get_config(name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    lens = [5, 11, 3, 14, 8, 2]
    news = [4, 7, 3, 5, 6, 4]
    prompts = [rng.integers(0, cfg.vocab, size=L).astype(np.int32)
               for L in lens]
    S_max = 48
    singles = [np.asarray(generate(
        model, params,
        {"tokens": jnp.asarray(p)[None], **_frontend_stub(cfg, 1)},
        m, S_max=S_max)[0]) for p, m in zip(prompts, news)]

    eng = ContinuousBatchingEngine(model, params, max_slots=3, S_max=S_max,
                                   bucket=8)
    for p, m in zip(prompts, news):
        eng.submit(p, max_new_tokens=m)
    outs = eng.run()
    assert len(outs) == len(lens)
    for i, (o, want) in enumerate(zip(outs, singles)):
        np.testing.assert_array_equal(o, want, err_msg=f"{name} req {i}")
    # early exit: 6 requests over 3 slots is 2 naive waves of max(news)
    # steps each; per-slot retirement + mid-decode admission must beat that
    assert eng.stats["decode_steps"] < 2 * max(news)


def test_engine_steady_state_no_recompile():
    """Once every prompt bucket has been seen, further waves must hit the
    compile cache only (the per-flush retrace bug, satellite 2)."""
    from repro.configs.tiny import TINY
    model = Model(TINY)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(2)
    eng = ContinuousBatchingEngine(model, params, max_slots=2, S_max=48,
                                   bucket=8)

    def wave():
        for L, m in [(5, 3), (11, 4), (3, 2), (9, 3)]:
            eng.submit(rng.integers(0, TINY.vocab, size=L), max_new_tokens=m)
        return eng.run()

    assert len(wave()) == 4
    misses_warm = eng.compile_cache.misses
    assert misses_warm > 0
    # a reused engine returns only THIS wave's results, not earlier ones
    assert len(wave()) == 4
    assert len(wave()) == 4
    assert eng.compile_cache.misses == misses_warm
    assert eng.compile_cache.hits > 0


def test_submit_validation():
    from repro.configs.tiny import TINY
    model = Model(TINY)
    params = model.init(jax.random.key(0))
    eng = ContinuousBatchingEngine(model, params, max_slots=2, S_max=32)
    with pytest.raises(ValueError):
        eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=0)
    with pytest.raises(ValueError):
        eng.submit(np.arange(40, dtype=np.int32), max_new_tokens=8)


def test_moe_capacity_bound_parity():
    """Per-row MoE dispatch: padded batched generation matches single even
    when expert capacity binds (capacity_factor=1.0, long + short rows
    co-batched) — in prefill AND in batched decode."""
    import dataclasses
    cfg = get_config("jamba-1.5-large-398b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    lens = [3, 29]
    prompts = [rng.integers(0, cfg.vocab, size=L).astype(np.int32)
               for L in lens]
    singles = _single_outputs(model, params, prompts, 4, S_max=40)
    toks = np.zeros((2, 32), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    gen = generate(model, params, {"tokens": jnp.asarray(toks)}, 4,
                   S_max=40, lengths=jnp.asarray(lens, jnp.int32))
    for i, want in enumerate(singles):
        np.testing.assert_array_equal(np.asarray(gen[i]), want,
                                      err_msg=f"row {i}")


def test_generate_loop_hoisted_no_retrace():
    """generate() must reuse one jitted decode loop across calls at the
    same shapes instead of re-tracing a fresh closure per flush; the
    compiled callables live on the Model instance, not a module global."""
    cfg = get_config("qwen3-4b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 8)),
                                   jnp.int32)}
    generate(model, params, batch, max_new_tokens=5)
    loop = _decode_loop(model, 0.0, 5)
    size_after_one = loop._cache_size()
    for _ in range(3):
        generate(model, params, batch, max_new_tokens=5)
    assert _decode_loop(model, 0.0, 5) is loop
    assert loop._cache_size() == size_after_one == 1
    assert ("decode_loop", 0.0, 5) in model._serve_jit_cache


def test_naive_engine_matches_single():
    """The right-pad fix in the naive flush engine (satellite 1)."""
    cfg = get_config("qwen2-7b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(4)
    lens = [5, 8, 3]
    prompts = [rng.integers(0, cfg.vocab, size=L).astype(np.int32)
               for L in lens]
    singles = _single_outputs(model, params, prompts, MAX_NEW, S_max=24)
    eng = ServeEngine(model, params, max_batch=3, bucket=8)
    for p in prompts:
        eng.submit(p, max_new_tokens=MAX_NEW)
    outs = eng.flush()
    for i, (o, want) in enumerate(zip(outs, singles)):
        np.testing.assert_array_equal(o, want[:len(o)], err_msg=f"req {i}")


def test_decode_backend_parity_end_to_end():
    """backend='pallas' and backend='ref' produce identical greedy tokens
    through the engine (gemma2: GQA + softcaps + local/global windows)."""
    cfg = get_config("gemma2-27b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=L).astype(np.int32)
               for L in (4, 9, 6)]
    outs = {}
    for backend in ("pallas", "ref"):
        eng = ContinuousBatchingEngine(model, params, max_slots=2, S_max=32,
                                       bucket=8, decode_backend=backend)
        for p in prompts:
            eng.submit(p, max_new_tokens=4)
        outs[backend] = eng.run()
    for a, b in zip(outs["pallas"], outs["ref"]):
        np.testing.assert_array_equal(a, b)
