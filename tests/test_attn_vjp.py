"""The flash-attention recompute backward (``jax.custom_vjp``):

* grad-parity matrix vs the dense differentiable route over
  softcap x sliding-window x GQA ratio x odd-S x per-row lengths;
* whole-model ``jax.grad`` parity under ``attn_backend="pallas"`` (the
  kernel VJP carries the model backward, fp32 tolerance vs dense);
* structural proof: the ``jax.grad``-under-jit jaxpr holds no [S, S]
  intermediates — the recompute backward never materializes scores;
* masked-key cotangents: dK/dV vanish past each row's length.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import check_no_dense_intermediates
from repro.configs.tiny import TINY
from repro.models import layers as L
from repro.models.transformer import ShardCtx

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True)
def _isolated_autotune(monkeypatch, tmp_path):
    """Keep block-size choices independent of any committed autotune
    table: traces during these tests see an empty table (128x128)."""
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_AUTOTUNE_DIR", str(tmp_path / "at"))
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def _qkv(S, H, KV, hd, B=2, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    return q, k, v


def _grads(backend, cfg, q, k, v, window, lengths):
    def loss(q, k, v):
        out = L.forward_attention(q, k, v, cfg, None, window=window,
                                  lengths=lengths, backend=backend)
        # position-dependent weighting so dq/dk/dv are structured, not
        # the all-ones cotangent a plain sum would produce
        w = jnp.arange(out.size, dtype=jnp.float32).reshape(out.shape)
        w = jnp.sin(w * 1e-3)
        if lengths is not None:
            # only positions < lengths[b] are meaningful: query rows the
            # window pushes fully past a short row's prefix are dead, and
            # the backends differ in the garbage they emit there
            pos = jnp.arange(out.shape[1])[None, :, None, None]
            w = jnp.where(pos < lengths[:, None, None, None], w, 0.0)
        return jnp.sum(out.astype(jnp.float32) * w)

    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


# (S, H, KV, softcap, window, lengths-fraction) — the satellite matrix:
# softcap x sliding-window x GQA ratio x odd-S x per-row lengths
MATRIX = [
    (64, 4, 2, 0.0, 0, None),       # base
    (64, 4, 2, 30.0, 0, None),      # softcap
    (64, 4, 2, 0.0, 24, None),      # sliding window
    (64, 4, 1, 0.0, 0, None),       # GQA ratio G=4
    (67, 4, 2, 0.0, 0, None),       # odd S (pad + trim path)
    (64, 4, 2, 0.0, 0, 0.5),        # per-row lengths
    (67, 4, 2, 20.0, 16, 0.75),     # everything at once
]


@pytest.mark.parametrize("S,H,KV,cap,window,lfrac", MATRIX)
def test_grad_parity_vs_dense(S, H, KV, cap, window, lfrac):
    cfg = TINY.replace(n_heads=H, n_kv_heads=KV, attn_softcap=cap)
    hd = cfg.resolved_head_dim
    q, k, v = _qkv(S, H, KV, hd)
    lengths = (None if lfrac is None
               else jnp.asarray([S, max(1, int(S * lfrac))], jnp.int32))
    gp = _grads("pallas", cfg, q, k, v, window, lengths)
    gd = _grads("dense", cfg, q, k, v, window, lengths)
    for name, a, b in zip("qkv", gp, gd):
        err = float(jnp.max(jnp.abs(a - b)))
        assert err < 5e-4, (name, err)


def test_dkv_zero_past_lengths():
    """Keys/values at positions >= lengths[b] receive exactly zero
    cotangent — the masked-key contract survives the backward."""
    S, H, KV, hd = 64, 4, 2, 16
    cfg = TINY.replace(n_heads=H, n_kv_heads=KV)
    q, k, v = _qkv(S, H, KV, hd)
    Lrow = S // 2
    lengths = jnp.asarray([S, Lrow], jnp.int32)
    _, dk, dv = _grads("pallas", cfg, q, k, v, 0, lengths)
    assert float(jnp.max(jnp.abs(dk[1, Lrow:]))) == 0.0
    assert float(jnp.max(jnp.abs(dv[1, Lrow:]))) == 0.0
    # ...and live keys do carry gradient
    assert float(jnp.max(jnp.abs(dv[1, :Lrow]))) > 0.0


def _model_grad(backend, S, seed=0):
    from repro.models import Model
    model = Model(TINY, ctx=ShardCtx(attn_backend=backend))
    params = model.init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, TINY.vocab, size=(2, S)), jnp.int32)}
    g = jax.grad(lambda p: model.loss(p, batch))(params)
    return params, batch, g


def test_model_grad_parity_pallas_vs_dense():
    """Acceptance: jax.grad of the whole-model forward resolves to the
    Pallas VJP under attn_backend='pallas' with fp32-level parity vs the
    dense route."""
    S = 320  # above ATTN_AUTO_MIN_S: the blockwise regime
    _, _, gp = _model_grad("pallas", S)
    _, _, gd = _model_grad("dense", S)
    flat_p, flat_d = jax.tree.leaves(gp), jax.tree.leaves(gd)
    for a, b in zip(flat_p, flat_d):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_model_grad_jaxpr_no_SS_and_uses_kernel():
    """The jax.grad-under-jit jaxpr walk: under attn_backend='pallas' the
    whole-model backward holds no [S, S] intermediates (the recompute
    kernels never materialize scores), and the pallas calls are actually
    in the trace.  S exceeds every non-sequence dim (vocab included) so
    the only way to trip the checker is a genuine [S, S] buffer."""
    from repro.models import Model
    S = 600
    model = Model(TINY, ctx=ShardCtx(attn_backend="pallas"))
    params = model.init(jax.random.key(0))
    batch = {"tokens": jnp.zeros((1, S), jnp.int32)}
    jaxpr = jax.make_jaxpr(jax.jit(jax.grad(
        lambda p: model.loss(p, batch))))(params)
    assert not check_no_dense_intermediates(jaxpr, S)
    assert "pallas_call" in str(jaxpr)


def test_grad_scope_auto_routes_through_kernel_vjp():
    """first_order's differentiable_attn scope at blockwise S: 'auto'
    resolves to the kernel VJP (the route the analyzer's first_order
    memory budget is sized against) and the step executes finitely."""
    from repro.models import Model
    from repro.train.first_order import make_train_step
    S = 320
    assert L.resolve_attn_backend("auto", TINY, S=S,
                                  differentiable=True) == "pallas"
    model = Model(TINY, ctx=ShardCtx(attn_backend="auto"))
    params = model.init(jax.random.key(0))
    batch = {"tokens": jnp.ones((1, S), jnp.int32)}
    init, step = make_train_step(lambda p, b: model.loss(p, b), lr=1e-3)
    new_params, _, loss = step(params, init(params), batch)
    assert np.isfinite(float(loss))
    assert any(not np.allclose(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(new_params)))
