"""Forward-attention backend parity (ISSUE 4 tentpole).

pallas (kernels/flash_attention.py) == online (jnp online softmax) ==
dense (materialized scores) through the unified ``forward_attention``
dispatch, over the full feature matrix: softcap on/off, sliding window
on/off, GQA ratios, odd (non-block-multiple) S, per-row right-pad lengths.

Plus the structural guarantee the dispatch exists for: a
``jax.make_jaxpr``-based proof that the pallas/online routes never allocate
an [S, S]-shaped score intermediate (and that the dense route does — the
checker is not vacuous).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import check_no_dense_intermediates
from repro.configs.tiny import TINY
from repro.models import layers as L
from repro.models.transformer import ShardCtx

BACKENDS = ("dense", "online", "pallas")


@pytest.fixture(autouse=True)
def _isolated_autotune(monkeypatch, tmp_path):
    """Resolver tests assert the *untuned* policy: point the autotune
    table at an empty dir so a committed runs/autotune table (or one
    written by other tests) can't redirect "auto"."""
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_AUTOTUNE_DIR", str(tmp_path / "at"))
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def _qkv(seed, B, S, H, KV, hd):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(k1, (B, S, H, hd)),
            jax.random.normal(k2, (B, S, KV, hd)),
            jax.random.normal(k3, (B, S, KV, hd)))


def _run(backend, q, k, v, cfg, *, window=0, lengths=None):
    return np.asarray(L.forward_attention(
        q, k, v, cfg, None, window=window, lengths=lengths,
        backend=backend), np.float32)


# ------------------------------------------------------------- matrix ------
@pytest.mark.parametrize("softcap", [0.0, 30.0])
@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("KV,G", [(4, 1), (2, 2), (1, 4)])
def test_backend_parity_matrix(softcap, window, KV, G):
    """All three backends agree within 1e-4 at an odd (non-block-multiple)
    S with per-row right-pad lengths."""
    B, S, hd = 3, 100, 16
    H = KV * G
    cfg = TINY.replace(n_heads=H, n_kv_heads=KV, attn_softcap=softcap)
    q, k, v = _qkv(hash((softcap, window, KV, G)) % 1000, B, S, H, KV, hd)
    lengths = jnp.asarray([S, 71, 13], jnp.int32)
    outs = {be: _run(be, q, k, v, cfg, window=window, lengths=lengths)
            for be in BACKENDS}
    # rows past a row's length are pad queries: their outputs are garbage
    # by contract, so compare valid rows only
    valid = (np.arange(S)[None, :]
             < np.asarray(lengths)[:, None])[:, :, None, None]
    for be in ("online", "pallas"):
        np.testing.assert_allclose(outs[be] * valid, outs["dense"] * valid,
                                   atol=1e-4, err_msg=be)


def test_backend_parity_no_lengths_block_multiple():
    cfg = TINY.replace(n_heads=4, n_kv_heads=2)
    q, k, v = _qkv(7, 2, 256, 4, 2, 32)
    outs = {be: _run(be, q, k, v, cfg) for be in BACKENDS}
    for be in ("online", "pallas"):
        np.testing.assert_allclose(outs[be], outs["dense"], atol=1e-4,
                                   err_msg=be)


def test_online_padded_kv_mask_matches_dense():
    """Satellite: online no longer falls back to dense on odd S and honors
    key-validity masking (here expressed as an arbitrary-prefix kv_mask)."""
    cfg = TINY.replace(n_heads=4, n_kv_heads=2)
    B, S = 2, 77
    q, k, v = _qkv(3, B, S, 4, 2, 16)
    lengths = jnp.asarray([50, 77], jnp.int32)
    kvm = (jnp.arange(S)[None, :] < lengths[:, None])
    got = L.online_gqa_attention(q, k, v, cfg, q_block=32, kv_block=32,
                                 kv_mask=kvm)
    want = _run("dense", q, k, v, cfg, lengths=lengths)
    valid = np.asarray(kvm)[:, :, None, None]
    np.testing.assert_allclose(np.asarray(got) * valid, want * valid,
                               atol=1e-4)


def test_online_unroll_padded_matches_scan():
    cfg = TINY.replace(n_heads=4, n_kv_heads=2)
    q, k, v = _qkv(11, 1, 100, 4, 2, 16)
    a = L.online_gqa_attention(q, k, v, cfg, q_block=32, kv_block=32,
                               unroll=False)
    b = L.online_gqa_attention(q, k, v, cfg, q_block=32, kv_block=32,
                               unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ------------------------------------------------- hypothesis property ------
def test_backend_parity_random_shapes():
    hypothesis = pytest.importorskip("hypothesis")
    import hypothesis.strategies as st

    hypothesis.settings.register_profile(
        "fast", max_examples=12, deadline=None,
        suppress_health_check=list(hypothesis.HealthCheck))
    hypothesis.settings.load_profile("fast")

    @hypothesis.given(
        seed=st.integers(0, 999),
        B=st.integers(1, 3),
        S=st.integers(9, 150),
        KV=st.sampled_from([1, 2]),
        G=st.sampled_from([1, 2, 4]),
        hd=st.sampled_from([16, 32]),
        window=st.sampled_from([0, 17]),
        softcap=st.sampled_from([0.0, 30.0]),
        frac=st.floats(0.2, 1.0),
    )
    def prop(seed, B, S, KV, G, hd, window, softcap, frac):
        H = KV * G
        cfg = TINY.replace(n_heads=H, n_kv_heads=KV, attn_softcap=softcap)
        q, k, v = _qkv(seed, B, S, H, KV, hd)
        lens = np.maximum(1, (np.linspace(frac, 1.0, B) * S)).astype(np.int32)
        lengths = jnp.asarray(lens)
        outs = {be: _run(be, q, k, v, cfg, window=window, lengths=lengths)
                for be in BACKENDS}
        valid = (np.arange(S)[None, :] < lens[:, None])[:, :, None, None]
        for be in ("online", "pallas"):
            np.testing.assert_allclose(outs[be] * valid,
                                       outs["dense"] * valid,
                                       atol=1e-4, err_msg=be)

    prop()


def test_self_attention_mask_extra_honors_lengths():
    """The dense mask_extra branch must still mask right-padded keys: with
    an all-true mask_extra it matches the lengths-only route exactly."""
    cfg = TINY.replace(n_heads=4, n_kv_heads=2)
    B, S, D = 2, 40, TINY.d_model
    hd = cfg.resolved_head_dim
    key = jax.random.key(5)
    kx, kq, kk, kv_, ko = jax.random.split(key, 5)
    x = jax.random.normal(kx, (B, S, D))
    p = {"wq": jax.random.normal(kq, (D, 4 * hd)) * 0.1,
         "wk": jax.random.normal(kk, (D, 2 * hd)) * 0.1,
         "wv": jax.random.normal(kv_, (D, 2 * hd)) * 0.1,
         "wo": jax.random.normal(ko, (4 * hd, D)) * 0.1}
    positions = jnp.arange(S)[None, :]
    lengths = jnp.asarray([S, 23], jnp.int32)
    ones = jnp.ones((1, S, S), bool)
    a = L.self_attention(x, p, cfg, positions, local=False,
                         mask_extra=ones, lengths=lengths)
    b = L.self_attention(x, p, cfg, positions, local=False,
                         ctx=ShardCtx(attn_backend="dense"), lengths=lengths)
    valid = (np.arange(S)[None, :] < np.asarray(lengths)[:, None])[:, :, None]
    np.testing.assert_allclose(np.asarray(a) * valid, np.asarray(b) * valid,
                               atol=1e-5)


# ------------------------------------------------ no-[S,S] jaxpr proof ------
@pytest.mark.parametrize("backend", ["pallas", "online"])
def test_flash_routes_allocate_no_SS_buffer(backend):
    """The blockwise routes never allocate an [S, S]-shaped intermediate —
    the structural property the attention dispatch exists to provide."""
    S, B, hd = 256, 1, 16
    cfg = TINY.replace(n_heads=4, n_kv_heads=2)
    q, k, v = _qkv(0, B, S, 4, 2, hd)

    def fn(q, k, v):
        return L.forward_attention(q, k, v, cfg, None, backend=backend)

    jaxpr = jax.make_jaxpr(fn)(q, k, v)
    assert not check_no_dense_intermediates(jaxpr, S), jaxpr


def test_dense_route_does_allocate_SS():
    """Checker sanity: the dense route's [B,KV,G,S,S] scores must trip it."""
    S = 256
    cfg = TINY.replace(n_heads=4, n_kv_heads=2)
    q, k, v = _qkv(0, 1, S, 4, 2, 16)

    def fn(q, k, v):
        return L.forward_attention(q, k, v, cfg, None, backend="dense")

    jaxpr = jax.make_jaxpr(fn)(q, k, v)
    offenders = check_no_dense_intermediates(jaxpr, S)
    assert offenders and any(
        sum(d >= S for d in o["shape"]) >= 2 for o in offenders)


def test_model_forward_flash_route_no_SS():
    """End to end through the model stack (what the ZO loss forwards run):
    ctx.attn_backend='pallas' keeps the whole training forward [S,S]-free.

    S exceeds every non-sequence model dim (vocab included) so the only way
    to trip the checker is a genuine [S, S] attention buffer."""
    from repro.models import Model
    S = 600
    model = Model(TINY, ctx=ShardCtx(attn_backend="pallas"))
    params = model.init(jax.random.key(0))
    batch = {"tokens": jnp.zeros((1, S), jnp.int32)}
    jaxpr = jax.make_jaxpr(lambda p, b: model.forward(p, b))(params, batch)
    assert not check_no_dense_intermediates(jaxpr, S)


# ---------------------------------------------------------- resolution ------
def test_resolve_attn_backend(monkeypatch):
    big, small = L.ATTN_AUTO_MIN_S, L.ATTN_AUTO_MIN_S - 1
    assert L.resolve_attn_backend("pallas", TINY) == "pallas"
    assert L.resolve_attn_backend("online", TINY) == "online"
    assert L.resolve_attn_backend("dense", TINY) == "dense"
    # auto: dense below the threshold; above it the fastest blockwise
    # route for the host — online while interpreting (this CPU container),
    # and without a measured autotune entry even compiled hosts only
    # assume the kernel wins from ATTN_PALLAS_MIN_S up (fixed-block
    # probes showed online ahead at moderate S)
    assert L.resolve_attn_backend("auto", TINY, S=small) == "dense"
    assert L.resolve_attn_backend("auto", TINY, S=big) == "online"
    assert L.resolve_attn_backend(None, TINY, S=big) == "online"
    monkeypatch.setattr("repro.kernels.ops._default_interpret",
                        lambda: False)
    cfg128 = TINY.replace(head_dim=128)
    assert L.resolve_attn_backend("auto", cfg128, S=big) == "online"
    assert L.resolve_attn_backend(
        "auto", cfg128, S=L.ATTN_PALLAS_MIN_S) == "pallas"
    # compiled, but head_dim off the 128-lane tile: jnp route
    assert L.resolve_attn_backend(
        "auto", TINY, S=L.ATTN_PALLAS_MIN_S) == "online"
    with pytest.raises(ValueError):
        L.resolve_attn_backend("cuda", TINY)


def test_resolve_attn_backend_mesh_and_legacy():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("model",))
    ctx = ShardCtx(mesh=mesh, batch_axes=(), model_axis="model")
    # sharded: jnp routes only (dense small, online large)
    assert L.resolve_attn_backend("auto", TINY, ctx, S=64) == "dense"
    assert L.resolve_attn_backend("auto", TINY, ctx, S=1024) == "online"
    # legacy zo_dp flag still routes online
    ctx2 = ShardCtx(online_attn=True)
    assert L.resolve_attn_backend("auto", TINY, ctx2, S=1024) == "online"


def test_grad_scope_resolves_differentiable():
    # grad traces prefer the kernel's recompute VJP at blockwise S
    # (bounded backward memory); explicit backends are honored as asked
    with L.differentiable_attn():
        assert L.resolve_attn_backend("auto", TINY, S=1024) == "pallas"
        assert L.resolve_attn_backend("auto", TINY, S=64) == "dense"
        assert L.resolve_attn_backend("pallas", TINY, S=64) == "pallas"
        assert L.resolve_attn_backend("dense", TINY, S=1024) == "dense"
        assert L.resolve_attn_backend("online", TINY, S=1024) == "online"
    assert L.resolve_attn_backend("auto", TINY, S=1024) == "online"


def test_first_order_grad_through_auto_backend():
    """jax.grad through the model loss works when the ctx asks for the
    pallas route: the kernel's recompute VJP carries the backward."""
    from repro.models import Model
    from repro.train.first_order import make_train_step
    model = Model(TINY, ctx=ShardCtx(attn_backend="pallas"))
    params = model.init(jax.random.key(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32)}
    init, step = make_train_step(lambda p, b: model.loss(p, b), lr=1e-3)
    new_params, _, loss = step(params, init(params), batch)
    assert np.isfinite(float(loss))
    assert any(not np.allclose(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(new_params)))
