"""Equivalence of the attention implementations (hypothesis property tests).

gqa_attention (repeat-KV oracle) == blocked_gqa_attention (q-chunked)
== online_gqa_attention (flash-style online softmax, §Perf pair 2)
== grouped_gqa_attention (decode path, §Perf pair 1).
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tiny import TINY
from repro.models import layers as L

hypothesis.settings.register_profile(
    "fast", max_examples=12, deadline=None,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("fast")


def _qkv(seed, B, S, H, KV, hd):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    return (jax.random.normal(k1, (B, S, H, hd)),
            jax.random.normal(k2, (B, S, KV, hd)),
            jax.random.normal(k3, (B, S, KV, hd)))


@hypothesis.given(
    seed=st.integers(0, 999),
    B=st.integers(1, 3),
    KV=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([16, 32]),
    window=st.sampled_from([0, 48]),
    softcap=st.sampled_from([0.0, 30.0]),
)
def test_online_matches_oracle(seed, B, KV, G, hd, window, softcap):
    S, H = 128, KV * G
    cfg = TINY.replace(n_heads=H, n_kv_heads=KV, attn_softcap=softcap)
    q, k, v = _qkv(seed, B, S, H, KV, hd)
    ref = L.gqa_attention(q, k, v, L.causal_mask(S, S, window), cfg, None)
    got = L.online_gqa_attention(q, k, v, cfg, window=window,
                                 q_block=32, kv_block=64)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=2e-3)


@hypothesis.given(
    seed=st.integers(0, 999),
    B=st.integers(1, 3),
    KV=st.sampled_from([1, 2, 4]),
    G=st.sampled_from([1, 2, 7]),
    W=st.sampled_from([64, 96]),
    frac=st.floats(0.1, 1.0),
)
def test_grouped_decode_matches_oracle(seed, B, KV, G, W, frac):
    """grouped_gqa_attention == gqa_attention for one-token decode."""
    H, hd = KV * G, 32
    cfg = TINY.replace(n_heads=H, n_kv_heads=KV)
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(k1, (B, 1, H, hd))
    k = jax.random.normal(k2, (B, W, KV, hd))
    v = jax.random.normal(k3, (B, W, KV, hd))
    cur = max(0, int(W * frac) - 1)
    valid = (jnp.arange(W)[None, None, :] <= cur)
    ref = L.gqa_attention(q, k, v, valid, cfg, None)
    got = L.grouped_gqa_attention(q, k, v, valid, cfg, None)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=2e-3)


def test_blocked_and_online_agree_with_full():
    cfg = TINY.replace(n_heads=4, n_kv_heads=2)
    q, k, v = _qkv(7, 2, 256, 4, 2, 32)
    full = L.gqa_attention(q, k, v, L.causal_mask(256, 256), cfg, None)
    blocked = L.blocked_gqa_attention(q, k, v, cfg, None, window=0,
                                      q_block=64)
    online = L.online_gqa_attention(q, k, v, cfg, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(online), np.asarray(full),
                               atol=2e-3)


def test_online_unroll_matches_scan():
    cfg = TINY.replace(n_heads=4, n_kv_heads=2)
    q, k, v = _qkv(11, 1, 128, 4, 2, 16)
    a = L.online_gqa_attention(q, k, v, cfg, q_block=32, kv_block=32,
                               unroll=False)
    b = L.online_gqa_attention(q, k, v, cfg, q_block=32, kv_block=32,
                               unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
