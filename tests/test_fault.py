"""The failure model (repro.fault + FederatedZO.run_round(faults=)):
deterministic FaultPlan schedules, dropout survivor parity, bit-exact
straggler replay, fault-aware CommLog accounting, GradIP gaps, and the
compiled-path report_mask dropout in make_fl_train_step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.configs.tiny import TINY
from repro.core import random_mask
from repro.core import virtual_path as VP
from repro.core.fl_step import make_fl_train_step
from repro.core.server import Client, FederatedZO
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.fault import NO_FAULTS, FaultPlan, RoundFaults
from repro.models import Model

SPEC = TaskSpec(vocab=min(TINY.vocab, 512))


@pytest.fixture(scope="module")
def prob():
    model = Model(TINY)
    params = model.init(jax.random.key(0))
    loss, per_example, _ = make_task_fns(model, SPEC)
    space = random_mask(params, density=1e-2, seed=0, balanced=False)
    return dict(params=params, loss=loss, per_example=per_example,
                space=space)


def mk_server(prob, n_clients=3, T=2, momentum=0.0, client_ids=None):
    fl = FLConfig(n_clients=n_clients, local_steps=T, batch_size=2,
                  server_momentum=momentum)
    ids = client_ids or list(range(n_clients))
    clients = [Client(i, sample_dataset(SPEC, 8, seed=i), 2) for i in ids]
    return FederatedZO(prob["loss"], prob["params"], prob["space"], fl,
                       clients)


def flat(tree):
    return np.concatenate([np.asarray(x).ravel()
                           for x in jax.tree.leaves(tree)])


# -- FaultPlan ---------------------------------------------------------------

def test_fault_plan_deterministic_and_bounded():
    a = FaultPlan(8, 10, drop_rate=0.3, late_rate=0.2, max_staleness=3,
                  seed=7, kill_rounds=(4,))
    b = FaultPlan(8, 10, drop_rate=0.3, late_rate=0.2, max_staleness=3,
                  seed=7, kill_rounds=(4,))
    for r in range(12):
        fa = a.round_faults(r)
        assert fa == b.round_faults(r)
        assert not (fa.drops & set(fa.late))  # a client fails one way
        assert all(1 <= d <= 3 for d in fa.late.values())
    assert a.kill_at(4) and not a.kill_at(3)
    assert a.round_faults(10) == NO_FAULTS  # past the schedule: clean
    s = a.summary()
    assert s["n_drop_events"] > 0 and s["n_late_events"] > 0
    assert a.round_faults(4).kill


def test_fault_plan_seed_changes_schedule():
    a = FaultPlan(8, 20, drop_rate=0.3, seed=0)
    b = FaultPlan(8, 20, drop_rate=0.3, seed=1)
    assert any(a.round_faults(r) != b.round_faults(r) for r in range(20))


def test_fault_plan_validation():
    with pytest.raises(ValueError):
        FaultPlan(4, 5, drop_rate=0.7, late_rate=0.5)  # rates sum > 1
    with pytest.raises(ValueError):
        FaultPlan(4, 5, drop_rate=-0.1)
    with pytest.raises(ValueError):
        FaultPlan(4, 5, late_rate=0.1, max_staleness=0)
    with pytest.raises(ValueError):
        FaultPlan(0, 5)


def test_round_faults_empty():
    assert NO_FAULTS.empty
    assert not RoundFaults(drops=frozenset({1})).empty
    assert not RoundFaults(late={2: 1}).empty
    assert not RoundFaults(kill=True).empty


# -- dropout -----------------------------------------------------------------

def test_dropout_survivor_parity(prob):
    """A round where client 2 is offline must equal, bit for bit, the
    same round run by a fleet that never contained client 2 (survivors'
    seeds/data/recon are untouched; FedAvg renormalizes over 2)."""
    gp = jnp.full((prob["space"].n,), 0.01, jnp.float32)
    full = mk_server(prob, n_clients=3)
    full.run_round(gp_vec=gp, faults=RoundFaults(drops=frozenset({2})))
    survivors = mk_server(prob, n_clients=2)
    survivors.run_round(gp_vec=gp)
    assert np.array_equal(flat(full.params), flat(survivors.params))
    # the dropped client: frozen pointer, explicit GradIP gap, no bytes
    assert full.clients[2].ptr == 0
    assert full.gradip_log[2] == [None]
    assert full.last_round_info["n_reporting"] == 2
    assert full.last_round_info["drops"] == [2]


def test_dropout_comm_counts_survivors_only(prob):
    T = 2
    srv = mk_server(prob, n_clients=3, T=T)
    srv.run_round(faults=RoundFaults(drops=frozenset({0})))
    per_up = 4 * T
    down = srv._down_bytes(T)
    assert srv.comm.up_bytes == 2 * per_up
    assert srv.comm.down_bytes == 2 * down


def test_zero_survivor_round_is_noop_update(prob):
    srv = mk_server(prob, n_clients=3)
    p0 = flat(srv.params)
    srv.run_round(faults=RoundFaults(drops=frozenset({0, 1, 2})))
    assert np.array_equal(p0, flat(srv.params))
    assert srv.round == 1 and srv.comm.up_bytes == 0
    assert srv.last_round_info["n_reporting"] == 0
    assert [c.ptr for c in srv.clients] == [0, 0, 0]


# -- stragglers ----------------------------------------------------------------

def test_straggler_upload_is_bitexact_and_gap_filled(prob):
    """A late client computes on the round's own seeds/data; its queued
    scalars and the arrival-time GradIP must bit-match the fault-free
    twin's round-0 values (the seed ladder makes stale replay exact)."""
    gp = jnp.full((prob["space"].n,), 0.01, jnp.float32)
    twin = mk_server(prob, n_clients=3)
    gs0 = twin.run_round(gp_vec=gp)

    srv = mk_server(prob, n_clients=3)
    reported = srv.run_round(gp_vec=gp, faults=RoundFaults(late={1: 1}))
    assert 1 not in reported  # upload in flight
    assert srv.gradip_log[1] == [None]
    assert len(srv._pending) == 1
    assert np.array_equal(srv._pending[0]["gs"], np.asarray(gs0[1]))
    assert srv.clients[1].ptr == twin.clients[1].ptr  # it did the work

    srv.run_round(gp_vec=gp)  # arrival round
    assert srv._pending == []
    assert np.array_equal(srv.gradip_log[1][0], twin.gradip_log[1][0])
    assert srv.last_round_info["arrived"][0][:2] == (1, 0)


def test_straggler_comm_bytes_settle_to_fault_free_totals(prob):
    """Late uploads are billed at arrival, downlinks at participation —
    once everything lands, totals equal the fault-free run's."""
    clean = mk_server(prob, n_clients=3)
    clean.run_round()
    clean.run_round()
    srv = mk_server(prob, n_clients=3)
    srv.run_round(faults=RoundFaults(late={0: 1, 2: 1}))
    up_mid = srv.comm.up_bytes
    srv.run_round()
    assert up_mid == 4 * 2  # only client 1's T=2 scalars billed so far
    assert srv.comm.up_bytes == clean.comm.up_bytes
    assert srv.comm.down_bytes == clean.comm.down_bytes


def test_staleness_bound_respected(prob):
    srv = mk_server(prob, n_clients=3)
    srv.run_round(faults=RoundFaults(late={1: 2}))
    srv.run_round()
    assert len(srv._pending) == 1  # not due yet
    srv.run_round()
    assert srv._pending == []


# -- aggregation + grouping ----------------------------------------------------

def test_aggregate_n_reporting():
    deltas = jnp.asarray([[2.0, 4.0], [4.0, 8.0]])
    np.testing.assert_allclose(np.asarray(VP.aggregate(deltas)),
                               [3.0, 6.0])
    np.testing.assert_allclose(np.asarray(VP.aggregate(deltas, 4)),
                               [1.5, 3.0])
    with pytest.raises(ValueError):
        VP.aggregate(deltas, 0)
    with pytest.raises(ValueError):
        VP.aggregate(jnp.zeros((0, 2)))


def test_mixed_T_groups_with_faults(prob):
    """Sorted-T grouping + faults: early-stopped clients (T=1 group) and
    full-T clients drop/straggle independently without double-running."""
    gp = jnp.full((prob["space"].n,), 0.01, jnp.float32)
    srv = mk_server(prob, n_clients=4)
    srv.early_stopped = {1, 3}
    srv.run_round(gp_vec=gp,
                  faults=RoundFaults(drops=frozenset({3}), late={0: 1}))
    assert srv.gradip_log[3] == [None]
    assert len(srv._pending) == 1 and srv._pending[0]["cid"] == 0
    assert srv._pending[0]["gs"].shape == (2,)  # full-T straggler
    assert srv.last_round_info["n_reporting"] == 2
    srv.run_round(gp_vec=gp)
    assert all(srv.gradip_log[c][0] is not None for c in (0, 1, 2))


# -- compiled-path dropout (fl_step) --------------------------------------------

def test_train_step_report_mask_matches_masked_mean(prob):
    n_clients, B = 4, 8
    step = make_fl_train_step(prob["per_example"], prob["space"],
                              eps=1e-3, lr=5e-2, n_clients=n_clients)
    jstep = jax.jit(step)
    batch = {k: jnp.asarray(v)
             for k, v in sample_dataset(SPEC, B, seed=5).items()}
    key = jax.random.key(3)
    _, g_clients, _ = jstep(prob["params"], key, batch)
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    p_m, g_m, metrics = jstep(prob["params"], key, batch, mask)
    assert np.array_equal(np.asarray(g_m), np.asarray(g_clients))
    want = float((g_clients[0] + g_clients[2]) / 2.0)
    np.testing.assert_allclose(float(metrics["g"]), want, rtol=1e-6)
    # all-ones mask == None (fault-free) to float equality of the update
    p_none, _, m_none = jstep(prob["params"], key, batch)
    p_ones, _, m_ones = jstep(prob["params"], key, batch, jnp.ones((4,)))
    np.testing.assert_allclose(float(m_none["g"]), float(m_ones["g"]),
                               rtol=1e-6)
    np.testing.assert_allclose(flat(p_none), flat(p_ones), atol=1e-7)
    # zero mask guard: no division blow-up
    _, _, m_zero = jstep(prob["params"], key, batch, jnp.zeros((4,)))
    assert np.isfinite(float(m_zero["g"]))
