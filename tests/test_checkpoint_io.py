"""Checkpoint file format (checkpoint/io.py): versioned, checksummed,
atomic — and every failure mode surfaces as CheckpointError, never a raw
msgpack/numpy error."""
import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro.checkpoint.io import (FORMAT_VERSION, CheckpointError,
                                 load_manifest, load_pytree, save_pytree)


def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "inner": {"b": jnp.ones((5,), jnp.bfloat16),
                      "n": jnp.asarray(7, jnp.int32)}}


def test_roundtrip_bitexact_and_meta(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, _tree(), metadata={"round": 3, "tag": "x"})
    meta, leaves = load_manifest(path)
    assert meta == {"round": 3, "tag": "x"}
    assert set(leaves) == {"['w']", "['inner']['b']", "['inner']['n']"}
    out = load_pytree(path, _tree())
    for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_loaded_arrays_are_writable(tmp_path):
    """Leaves must be copied out of msgpack's read-only buffer view."""
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, {"w": np.zeros((4,), np.float32)})
    _, leaves = load_manifest(path)
    leaves["['w']"][0] = 1.0  # would raise on a frombuffer view


def test_corrupt_leaf_byte_fails_crc(tmp_path):
    """Flip one byte of a leaf's payload on disk: the CRC must catch it."""
    path = str(tmp_path / "ckpt.msgpack")
    marker = np.full((64,), 0x5A5A5A5A, np.uint32)  # distinctive byte run
    save_pytree(path, {"w": marker, "ok": np.arange(3, dtype=np.int64)})
    blob = bytearray(open(path, "rb").read())
    i = blob.find(marker.tobytes())
    assert i > 0, "marker bytes not found in file"
    blob[i + 17] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    with pytest.raises(CheckpointError, match="CRC32"):
        load_manifest(path)
    with pytest.raises(CheckpointError, match="CRC32"):
        load_pytree(path, {"w": marker, "ok": np.arange(3, dtype=np.int64)})


def test_truncated_file(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, _tree())
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[: len(blob) // 2])
    with pytest.raises(CheckpointError, match="truncated or corrupt"):
        load_manifest(path)


def test_missing_file(tmp_path):
    with pytest.raises(CheckpointError, match="cannot read"):
        load_manifest(str(tmp_path / "nope.msgpack"))


def test_version_mismatch(tmp_path):
    path = str(tmp_path / "old.msgpack")
    payload = {"version": FORMAT_VERSION - 1, "meta": {}, "leaves": {}}
    open(path, "wb").write(msgpack.packb(payload, use_bin_type=True))
    with pytest.raises(CheckpointError, match="format version"):
        load_manifest(path)


def test_not_a_manifest(tmp_path):
    path = str(tmp_path / "junk.msgpack")
    open(path, "wb").write(msgpack.packb([1, 2, 3]))
    with pytest.raises(CheckpointError, match="not a checkpoint"):
        load_manifest(path)


def test_missing_leaf_and_shape_mismatch(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, {"w": np.zeros((2, 2), np.float32)})
    with pytest.raises(CheckpointError, match="missing leaf"):
        load_pytree(path, {"w": np.zeros((2, 2), np.float32),
                           "extra": np.zeros((1,), np.float32)})
    with pytest.raises(CheckpointError, match="shape mismatch"):
        load_pytree(path, {"w": np.zeros((4,), np.float32)})


def test_atomic_write_leaves_no_tmp(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    save_pytree(path, _tree())
    save_pytree(path, _tree())  # overwrite in place
    assert os.listdir(tmp_path) == ["ckpt.msgpack"]
