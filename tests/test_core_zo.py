"""Properties of the sparse-ZO machinery: estimator correctness, virtual-path
exactness (hypothesis), seed determinism, space algebra."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DenseSpace, make_local_run, projected_gradient,
                        random_mask, reconstruct_delta, reconstruct_grad_vecs,
                        round_keys)
from repro.core.zo import local_step

hypothesis.settings.register_profile(
    "fast", max_examples=15, deadline=None,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("fast")


def quad_params(key, d=24):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (d,)), "b": jax.random.normal(k2, (4, 6))}


def quad_loss(params, batch):
    v = jnp.concatenate([params["a"], params["b"].reshape(-1)])
    return 0.5 * jnp.sum((v - batch["target"]) ** 2)


def test_projected_gradient_matches_directional_derivative():
    params = quad_params(jax.random.key(0))
    batch = {"target": jnp.arange(48.0) / 48.0}
    space = DenseSpace(params)
    z = space.sample_z(jax.random.key(1))
    delta = jnp.zeros((space.n,))
    g = projected_gradient(quad_loss, params, space, delta, z, 1e-4, batch)
    grad = jax.grad(quad_loss)(params, batch)
    expected = float(jnp.dot(space.slice(grad), z))
    assert abs(float(g) - expected) < 1e-2 * max(1.0, abs(expected))


def test_zo_estimator_unbiased():
    """E[g * z] ~= m (.) grad  (Lemma B.8) — statistical check."""
    params = quad_params(jax.random.key(0))
    batch = {"target": jnp.zeros(48)}
    space = random_mask(params, density=0.25, seed=1)
    grad_masked = space.slice(jax.grad(quad_loss)(params, batch))

    def one(key):
        z = space.sample_z(key)
        g = projected_gradient(quad_loss, params, space,
                               jnp.zeros(space.n), z, 1e-4, batch)
        return g * z

    keys = jax.random.split(jax.random.key(42), 4000)
    est = jnp.mean(jax.vmap(one)(keys), axis=0)
    err = float(jnp.linalg.norm(est - grad_masked)
                / (jnp.linalg.norm(grad_masked) + 1e-9))
    assert err < 0.15, err


@hypothesis.given(T=st.integers(1, 8), seed=st.integers(0, 10_000),
                  lr=st.floats(1e-4, 1e-1), density=st.floats(0.05, 1.0))
def test_virtual_path_exactness(T, seed, lr, density):
    """Paper Alg. 2 step 2: the server's reconstruction from (seeds, scalars)
    equals the client's local trajectory exactly."""
    params = quad_params(jax.random.key(3))
    space = random_mask(params, density=density, seed=seed)
    keys = round_keys(seed, 0, T)
    targets = jax.random.normal(jax.random.key(seed + 1), (T, 48))
    batches = {"target": targets}
    run = make_local_run(quad_loss, space, eps=1e-3, lr=lr)
    delta_client, gs = run(params, keys, batches,
                           jnp.zeros((space.n,), jnp.float32))
    delta_server = reconstruct_delta(space, keys, gs, lr)
    np.testing.assert_allclose(np.asarray(delta_client),
                               np.asarray(delta_server), atol=1e-6)


def test_reconstructed_grad_vecs_shape_and_value():
    params = quad_params(jax.random.key(4))
    space = random_mask(params, density=0.5, seed=2)
    keys = round_keys(7, 0, 3)
    gs = jnp.asarray([1.0, -2.0, 0.5])
    vecs = reconstruct_grad_vecs(space, keys, gs)
    assert vecs.shape == (3, space.n)
    z0 = space.sample_z(keys[0])
    np.testing.assert_allclose(vecs[0], gs[0] * z0, atol=1e-7)


def test_seed_ladder_deterministic_and_distinct():
    a = round_keys(0, 3, 5)
    b = round_keys(0, 3, 5)
    c = round_keys(0, 4, 5)
    assert jnp.array_equal(jax.random.key_data(a), jax.random.key_data(b))
    assert not jnp.array_equal(jax.random.key_data(a), jax.random.key_data(c))


@hypothesis.given(density=st.floats(0.02, 1.0), seed=st.integers(0, 1000))
def test_space_add_slice_roundtrip(density, seed):
    """slice(add(0, v)) == v for any masked space (coordinates are disjoint)."""
    params = quad_params(jax.random.key(5))
    space = random_mask(params, density=density, seed=seed)
    v = jax.random.normal(jax.random.key(seed), (space.n,))
    zeros = jax.tree.map(jnp.zeros_like, params)
    out = space.slice(space.add(zeros, v))
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), atol=1e-6)


def test_local_step_reduces_quadratic_loss_on_average():
    # Dense ZO-SGD on a d-dim quadratic contracts in expectation iff
    # 1 - 2*lr + lr^2 (d+2) < 1  =>  lr < 2/(d+2).  d=48 here, so lr must
    # be well below 4e-2; lr=1e-2 gives factor ~0.985/step.
    params = quad_params(jax.random.key(6))
    batch = {"target": jnp.zeros(48)}
    space = DenseSpace(params)
    delta = jnp.zeros((space.n,))
    l0 = float(quad_loss(params, batch))
    for i in range(80):
        delta, g = local_step(quad_loss, params, space, delta,
                              jax.random.key(100 + i), 1e-3, 1e-2, batch)
    l1 = float(quad_loss(space.add(params, delta), batch))
    assert l1 < l0


@hypothesis.given(T=st.integers(1, 5), K=st.integers(2, 4),
                  seed=st.integers(0, 1000))
def test_virtual_path_exactness_multi_direction(T, K, seed):
    """Beyond-paper n_dirs>1: server reconstruction from [T,K] scalars
    still replays the client trajectory exactly."""
    from repro.core.zo import make_local_run

    params = quad_params(jax.random.key(3))
    space = random_mask(params, density=0.5, seed=seed)
    keys = round_keys(seed, 0, T)
    targets = jax.random.normal(jax.random.key(seed + 1), (T, 48))
    run = make_local_run(quad_loss, space, eps=1e-3, lr=1e-2, n_dirs=K)
    delta_client, gs = run(params, keys, {"target": targets},
                           jnp.zeros((space.n,), jnp.float32))
    assert gs.shape == (T, K)
    delta_server = reconstruct_delta(space, keys, gs, 1e-2)
    np.testing.assert_allclose(np.asarray(delta_client),
                               np.asarray(delta_server), atol=1e-6)


def test_multi_direction_reduces_estimator_variance():
    """Var of the K-direction averaged estimator ~ Var/K (Lemma B.7)."""
    from repro.core.zo import local_step

    params = quad_params(jax.random.key(8))
    batch = {"target": jnp.zeros(48)}
    space = random_mask(params, density=0.5, seed=0)
    grad = space.slice(jax.grad(quad_loss)(params, batch))

    def est_err(key, n_dirs):
        d0 = jnp.zeros((space.n,))
        d1, _ = local_step(quad_loss, params, space, d0, key, 1e-4, 1.0,
                           batch, n_dirs=n_dirs)
        return jnp.sum((-(d1 - d0) - grad) ** 2)  # lr=1 => update = -est

    keys = jax.random.split(jax.random.key(99), 300)
    v1 = float(jnp.mean(jax.vmap(lambda k: est_err(k, 1))(keys)))
    v4 = float(jnp.mean(jax.vmap(lambda k: est_err(k, 4))(keys)))
    assert v4 < 0.5 * v1, (v1, v4)
