"""The uplink quantizer (core/quantize.py): pow2-scale roundtrip error
bounds, bit-exact idempotence (the exact-replay invariant), stochastic-
rounding unbiasedness, wire byte accounting, host<->jax parity, and the
codec registry.  Property-test variants run when hypothesis is
installed; the deterministic seeded versions always run."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import (E_MAX, E_MIN, QMAX, FloatWire,
                                 IdentityCodec, IntCodec, QuantSpec, Wire,
                                 decode, encode, make_codec, pack_codes,
                                 pow2_exponent, quantize_roundtrip,
                                 unpack_codes, wire_nbytes)


def rand(n, scale, seed):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n) * scale).astype(np.float32)


# -- exponent + grid geometry ------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8])
def test_pow2_exponent_minimal_and_covering(bits):
    qmax = QMAX[bits]
    amax = np.abs(rand(256, 1.0, 0)) * np.float32(10.0) ** \
        np.linspace(-6, 6, 256, dtype=np.float32)
    e = pow2_exponent(amax, bits)
    cover = np.ldexp(np.float32(qmax), e) >= amax
    assert cover.all()  # qmax * 2^e covers amax ...
    tighter = np.ldexp(np.float32(qmax), e - 1) >= amax
    assert not tighter[(e > E_MIN) & (amax > 0)].any()  # ... minimally
    assert e.dtype == np.int32 and (e >= E_MIN).all() and (e <= E_MAX).all()


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("stochastic", [False, True])
def test_roundtrip_error_bounded_by_grid_step(bits, stochastic):
    """|x_hat - x| <= 2^e per chunk, and 2^e <= 2*amax/qmax by exponent
    minimality — the quantizer's accuracy contract at any scale."""
    for scale in (1e-6, 1e-2, 1.0, 3e4):
        x = rand(512, scale, 3)
        rng = np.random.default_rng(7) if stochastic else None
        w = encode(x, bits, chunk=8, rng=rng)
        x_hat = decode(w)
        step = np.ldexp(np.float32(1), w.exps.astype(np.int32))
        err = np.abs(x_hat - x).reshape(-1, 8)
        bound = step[:, None] * (1.0 if stochastic else 0.5)
        assert (err <= bound + 1e-30).all()
        amax = np.abs(x).reshape(-1, 8).max(1)
        assert (step <= 2.0 * amax / QMAX[bits] + 1e-30).all()
        assert (np.abs(w.codes) <= QMAX[bits]).all()


@pytest.mark.parametrize("bits", [4, 8])
def test_idempotence_bit_exact(bits):
    """decode(encode(x_hat)) == x_hat bitwise for on-grid x_hat — under
    nearest AND stochastic re-encoding (on-grid values have no
    fractional part to randomize).  This is the exact-replay keystone:
    the server's re-encode of what the client applied is lossless."""
    x = rand(257, 1.0, 5)  # odd n: exercises int4 nibble padding
    for chunk in (1, 8):
        x_hat = decode(encode(x, bits, chunk, np.random.default_rng(0)))
        again = decode(encode(x_hat, bits, chunk))  # nearest
        np.testing.assert_array_equal(again, x_hat)
        rng = np.random.default_rng(123)
        stoch = decode(encode(x_hat, bits, chunk, rng))
        np.testing.assert_array_equal(stoch, x_hat)
        # and the cycle is stable forever after
        np.testing.assert_array_equal(decode(encode(again, bits, chunk)),
                                      x_hat)


@pytest.mark.parametrize("bits", [4, 8])
def test_stochastic_rounding_unbiased(bits):
    """mean over many independent stochastic roundtrips converges to x
    (within 5 sigma of the Bernoulli variance bound)."""
    x = rand(64, 1.0, 11)
    n_rep = 3000
    rng = np.random.default_rng(42)
    acc = np.zeros_like(x, np.float64)
    step = None
    for _ in range(n_rep):
        w = encode(x, bits, chunk=64, rng=rng)
        acc += decode(w)
        step = np.ldexp(np.float64(1), int(w.exps[0]))
    mean = acc / n_rep
    sigma = step / 2 / math.sqrt(n_rep)  # Bernoulli var <= (step/2)^2
    assert np.abs(mean - x).max() <= 5 * sigma


# -- wire format -------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("n", [1, 7, 8, 257])
def test_wire_bytes_match_serialization(bits, n):
    x = rand(n, 1.0, n)
    for chunk in (1, 4):
        w = encode(x, bits, chunk)
        assert w.nbytes == wire_nbytes(n, bits, chunk) == len(w.tobytes())
    ident = IdentityCodec()
    fw = ident.encode(x)
    assert fw.nbytes == ident.nbytes(n) == 4 * n == len(fw.tobytes())


def test_pack_unpack_roundtrip_odd_n():
    rng = np.random.default_rng(0)
    for bits in (4, 8):
        codes = rng.integers(-QMAX[bits], QMAX[bits] + 1,
                             size=13).astype(np.int8)
        raw = pack_codes(codes, bits)
        assert len(raw) == (13 * bits + 7) // 8
        np.testing.assert_array_equal(unpack_codes(raw, bits, 13), codes)


def test_wire_decode_preserves_shape():
    x = rand(12, 1.0, 2).reshape(3, 4)
    w = encode(x, 8, chunk=4)
    assert isinstance(w, Wire) and decode(w).shape == (3, 4)


# -- host <-> jax parity (the server re-encode must bit-match the
# client's in-loop roundtrip) ------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8])
def test_jax_nearest_bitmatches_host_codec(bits):
    x = rand(512, 1.0, 17)
    host = decode(encode(x, bits, chunk=1))
    dev = np.asarray(jax.jit(
        lambda g: quantize_roundtrip(g, jax.random.key(0), bits,
                                     stochastic=False))(jnp.asarray(x)))
    np.testing.assert_array_equal(dev, host)


@pytest.mark.parametrize("bits", [4, 8])
def test_jax_stochastic_passes_on_grid_values_unchanged(bits):
    """Client-side stochastic roundtrip applied to an already-on-grid
    value is the identity for ANY key — so the server's nearest
    re-encode of the client's applied value is bit-exact."""
    x_hat = decode(encode(rand(128, 1.0, 23), bits, chunk=1,
                          rng=np.random.default_rng(1)))
    for seed in (0, 1, 99):
        out = np.asarray(quantize_roundtrip(
            jnp.asarray(x_hat), jax.random.key(seed), bits,
            stochastic=True))
        np.testing.assert_array_equal(out, x_hat)


def test_quant_spec_uses_fold_stream():
    """QuantSpec.apply folds QUANT_FOLD into the step key: the rounding
    noise stream is disjoint from the raw key's other uses but still a
    pure function of it (resume-safe)."""
    g = jnp.asarray(rand(32, 1.0, 31))
    key = jax.random.key(4)
    spec = QuantSpec(bits=8, stochastic=True)
    a, b = spec.apply(g, key), spec.apply(g, key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    from repro.core.quantize import QUANT_FOLD
    direct = quantize_roundtrip(g, jax.random.fold_in(key, QUANT_FOLD),
                                8, True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(direct))


# -- codec registry ----------------------------------------------------------

def test_make_codec_parsing():
    assert isinstance(make_codec("none"), IdentityCodec)
    assert isinstance(make_codec(""), IdentityCodec)
    c8 = make_codec("int8")
    assert isinstance(c8, IntCodec) and c8.bits == 8 and c8.stochastic
    c4n = make_codec("int4-nearest")
    assert c4n.bits == 4 and not c4n.stochastic
    assert c4n.spec == "int4-nearest" and c8.spec == "int8"
    assert make_codec("int8").jax_spec() == QuantSpec(8, True)
    assert make_codec("none").jax_spec() is None
    with pytest.raises(ValueError):
        make_codec("int16")
    with pytest.raises(ValueError):
        IntCodec(bits=3)
    with pytest.raises(ValueError):
        IntCodec(bits=8, chunk=0)


def test_identity_codec_roundtrip_is_bitwise():
    x = rand(64, 1.0, 41)
    c = make_codec("none")
    w = c.encode(x)
    assert isinstance(w, FloatWire)
    np.testing.assert_array_equal(c.decode(w), x)


# -- hypothesis property tests (skipped when hypothesis is absent) -----------

def test_property_roundtrip_invariants():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    given, settings = hyp.given, hyp.settings

    @given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32),
                    min_size=1, max_size=64),
           st.sampled_from([4, 8]), st.integers(1, 8),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=200, deadline=None)
    def check(xs, bits, chunk, seed):
        x = np.asarray(xs, np.float32)
        rng = np.random.default_rng(seed)
        w = encode(x, bits, chunk, rng)
        x_hat = decode(w)
        # error bound per chunk
        n_chunks = w.exps.size
        pad = n_chunks * chunk - x.size
        g = np.concatenate([x, np.zeros((pad,), np.float32)])
        step = np.ldexp(np.float32(1), w.exps.astype(np.int32))
        err = np.abs(np.concatenate([x_hat.ravel(),
                                     np.zeros((pad,), np.float32)]) - g)
        assert (err.reshape(n_chunks, chunk) <= step[:, None]).all()
        # idempotence, both re-encode modes
        np.testing.assert_array_equal(decode(encode(x_hat, bits, chunk)),
                                      x_hat)
        np.testing.assert_array_equal(
            decode(encode(x_hat, bits, chunk, np.random.default_rng(1))),
            x_hat)
        # byte accounting
        assert w.nbytes == wire_nbytes(x.size, bits, chunk) \
            == len(w.tobytes())

    check()


def test_property_unbiasedness():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.floats(-100.0, 100.0, allow_nan=False, width=32),
               st.sampled_from([4, 8]))
    @hyp.settings(max_examples=30, deadline=None)
    def check(x0, bits):
        x = np.full((16,), x0, np.float32)
        rng = np.random.default_rng(0)
        n_rep = 2000
        acc = np.zeros((16,), np.float64)
        step = None
        for _ in range(n_rep):
            w = encode(x, bits, chunk=16, rng=rng)
            acc += decode(w)
            step = np.ldexp(np.float64(1), int(w.exps[0]))
        sigma = step / 2 / math.sqrt(16 * n_rep)  # pooled over coords
        assert abs(acc.mean() / n_rep - np.float64(x0)) <= 6 * sigma

    check()
