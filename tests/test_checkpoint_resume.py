"""Bit-exact checkpoint/resume of the federated server (DESIGN.md §11).

In-process: save at round r, restore into a *fresh* server, run both to
R — params, GradIP logs, CommLog, client pointers, velocity and history
must be bit-identical, including across plan=None <-> 1x1 FLShardPlan
(mesh-reshape restore) and through fault rounds.  The full cross-process
drill — SIGKILL mid-round on a 2x2 mesh, resume unsharded — runs
``tools/kill_recover.py`` in a subprocess with forced host devices.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import CheckpointError
from repro.configs.base import FLConfig
from repro.configs.tiny import TINY
from repro.core import random_mask
from repro.core.server import Client, FederatedZO
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.fault import FaultPlan
from repro.models import Model

REPO = os.path.join(os.path.dirname(__file__), "..")
TOOL = os.path.join(REPO, "tools", "kill_recover.py")
SPEC = TaskSpec(vocab=min(TINY.vocab, 512))


@pytest.fixture(scope="module")
def prob():
    model = Model(TINY)
    params = model.init(jax.random.key(0))
    loss, _, evaluate = make_task_fns(model, SPEC)
    space = random_mask(params, density=1e-2, seed=0, balanced=False)
    gp = jnp.full((space.n,), 0.01, jnp.float32)
    return dict(params=params, loss=loss, evaluate=evaluate, space=space,
                gp=gp)


def mk_server(prob, plan=None, momentum=0.5, n_clients=3, T=2):
    fl = FLConfig(n_clients=n_clients, local_steps=T, batch_size=2,
                  server_momentum=momentum, zo_backend="ref")
    clients = [Client(i, sample_dataset(SPEC, 8, seed=i), 2)
               for i in range(n_clients)]
    return FederatedZO(prob["loss"], prob["params"], prob["space"], fl,
                       clients, eval_fn=prob["evaluate"], plan=plan)


def flat(tree):
    return np.concatenate([np.asarray(x, np.float32).ravel()
                           for x in jax.tree.leaves(tree)])


def assert_servers_equal(a, b):
    assert np.array_equal(flat(a.params), flat(b.params))
    assert (a.comm.up_bytes, a.comm.down_bytes) == \
        (b.comm.up_bytes, b.comm.down_bytes)
    assert a.round == b.round
    assert [c.ptr for c in a.clients] == [c.ptr for c in b.clients]
    assert a.early_stopped == b.early_stopped
    assert a.history == b.history
    for cid in a.gradip_log:
        ea, eb = a.gradip_log[cid], b.gradip_log[cid]
        assert len(ea) == len(eb)
        for u, v in zip(ea, eb):
            assert (u is None) == (v is None)
            if u is not None:
                assert np.array_equal(u, v)
    if a.velocity is None:
        assert b.velocity is None
    else:
        assert np.array_equal(np.asarray(a.velocity),
                              np.asarray(b.velocity))


def run_rounds(srv, n, prob, fault_plan=None):
    for _ in range(n):
        faults = (fault_plan.round_faults(srv.round)
                  if fault_plan is not None else None)
        srv.run_round(gp_vec=prob["gp"], faults=faults)


def test_resume_bitexact_unsharded(prob, tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    ref = mk_server(prob)
    run_rounds(ref, 4, prob)
    donor = mk_server(prob)
    run_rounds(donor, 2, prob)
    donor.save_checkpoint(path)
    fresh = mk_server(prob)
    meta = fresh.load_checkpoint(path)
    assert meta["round"] == 2
    run_rounds(fresh, 2, prob)
    assert_servers_equal(ref, fresh)


def test_resume_through_fault_rounds(prob, tmp_path):
    """The fault schedule is rebuilt from flags on resume (FaultPlan is
    deterministic), so a run interrupted inside a faulty stretch —
    pending straggler uploads in flight — continues bit-exactly."""
    path = str(tmp_path / "ckpt.msgpack")
    fp = FaultPlan(3, 6, drop_rate=0.2, late_rate=0.3, max_staleness=2,
                   seed=5)
    ref = mk_server(prob, momentum=0.0)
    run_rounds(ref, 6, prob, fp)
    donor = mk_server(prob, momentum=0.0)
    run_rounds(donor, 3, prob, fp)
    donor.save_checkpoint(path)
    fresh = mk_server(prob, momentum=0.0)
    fresh.load_checkpoint(path)
    assert len(fresh._pending) == len(donor._pending)
    for p, q in zip(fresh._pending, donor._pending):
        assert (p["arrive"], p["cid"], p["src_round"], p["gip_idx"]) == \
            (q["arrive"], q["cid"], q["src_round"], q["gip_idx"])
        assert np.array_equal(p["gs"], q["gs"])
    run_rounds(fresh, 3, prob, FaultPlan(3, 6, drop_rate=0.2,
                                         late_rate=0.3, max_staleness=2,
                                         seed=5))
    assert_servers_equal(ref, fresh)


def test_mesh_reshape_restore_both_directions(prob, tmp_path):
    """plan=None -> 1x1 FLShardPlan and back: checkpoints store host
    arrays, restore re-places per the *target* plan, values unchanged."""
    from repro.sharding.fl import make_fl_plan
    plan = make_fl_plan(spec="1x1")
    ref = mk_server(prob)
    run_rounds(ref, 4, prob)

    # unsharded donor -> sharded survivor
    p1 = str(tmp_path / "a.msgpack")
    donor = mk_server(prob)
    run_rounds(donor, 2, prob)
    donor.save_checkpoint(p1)
    onto_mesh = mk_server(prob, plan=plan)
    onto_mesh.load_checkpoint(p1)
    run_rounds(onto_mesh, 2, prob)
    assert_servers_equal(ref, onto_mesh)

    # sharded donor -> unsharded survivor
    p2 = str(tmp_path / "b.msgpack")
    donor_m = mk_server(prob, plan=plan)
    run_rounds(donor_m, 2, prob)
    donor_m.save_checkpoint(p2)
    off_mesh = mk_server(prob)
    off_mesh.load_checkpoint(p2)
    run_rounds(off_mesh, 2, prob)
    assert_servers_equal(ref, off_mesh)


def test_early_stop_flags_survive_resume(prob, tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    ref = mk_server(prob)
    ref.early_stopped = {1}
    run_rounds(ref, 3, prob)
    donor = mk_server(prob)
    donor.early_stopped = {1}
    run_rounds(donor, 1, prob)
    donor.save_checkpoint(path)
    fresh = mk_server(prob)  # no flags set: must come from the file
    fresh.load_checkpoint(path)
    assert fresh.early_stopped == {1}
    run_rounds(fresh, 2, prob)
    assert_servers_equal(ref, fresh)


def test_config_mismatch_refused(prob, tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    donor = mk_server(prob, T=2)
    donor.run_round()
    donor.save_checkpoint(path)
    other_T = mk_server(prob, T=3)
    with pytest.raises(CheckpointError, match="config mismatch"):
        other_T.load_checkpoint(path)
    fewer = mk_server(prob, n_clients=2)
    with pytest.raises(CheckpointError, match="config mismatch"):
        fewer.load_checkpoint(path)


# -- the cross-process drill: die on a 2x2 mesh, recover unsharded ------------

@pytest.fixture(scope="module")
def kill_recover_report(tmp_path_factory):
    out = tmp_path_factory.mktemp("kr") / "report.json"
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, TOOL, "--rounds", "4", "--kill-at", "2",
         "--mesh-b", "2x2", "--json", str(out)],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    with open(out) as f:
        return json.load(f)


def test_kill_recover_sigkill_observed(kill_recover_report):
    checks = kill_recover_report["checks"]
    assert checks["victim_sigkilled"]
    assert checks["latest_at_kill_round"]
    assert checks["resumed_from_kill_round"]


def test_kill_recover_final_state_bitexact(kill_recover_report):
    """Recovered-from-SIGKILL final checkpoint == uninterrupted run's,
    with the victim sharded 2x2 and the survivor unsharded."""
    checks = kill_recover_report["checks"]
    assert checks["leaves_bitmatch"]
    for field in ("round", "up_bytes", "down_bytes", "ptrs", "history"):
        assert checks[f"meta_{field}_equal"], field
