"""Sharded-vs-single-device parity of the federated ZO round (ISSUE 5).

The mesh needs forced host devices *before* jax initializes, so the heavy
check runs ``tools/fl_mesh_parity.py`` in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` and asserts on its
JSON report: round-aggregated params and GradIP trajectories bit-match
across 1x1 and 2x2 meshes, VPCS flags and CommLog byte accounting are
identical, and the ``make_fl_train_loop`` mesh route agrees to tolerance.

The in-process tests cover the pieces that don't need devices: the GradIP
reduction dispatch (pallas kernel vs jnp dot) and the mesh-spec parsing.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import random_mask
from repro.core.gradip import _resolve_gradip_backend, gradip_trajectory
from repro.core.seeds import round_keys

REPO = os.path.join(os.path.dirname(__file__), "..")
TOOL = os.path.join(REPO, "tools", "fl_mesh_parity.py")


@pytest.fixture(scope="module")
def parity_report(tmp_path_factory):
    out = tmp_path_factory.mktemp("parity") / "report.json"
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, TOOL, "--meshes", "1x1,2x2", "--json", str(out)],
        env=env, capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    with open(out) as f:
        return json.load(f)


def test_round_aggregate_bitmatch_across_meshes(parity_report):
    for spec in ("1x1", "2x2"):
        assert parity_report["meshes"][spec]["params_bitmatch"], spec


def test_gradip_trajectories_bitmatch_across_meshes(parity_report):
    for spec in ("1x1", "2x2"):
        assert parity_report["meshes"][spec]["gradip_bitmatch"], spec


def test_vpcs_flags_equal_across_meshes(parity_report):
    for spec in ("1x1", "2x2"):
        assert parity_report["meshes"][spec]["vpcs_flags_equal"], spec


def test_comm_bytes_accounting_invariant_under_sharding(parity_report):
    """The FL protocol traffic (scalar uploads, seed/scalar downlinks) is a
    property of the algorithm, not of the round's mesh layout."""
    for spec in ("1x1", "2x2"):
        assert parity_report["meshes"][spec]["comm_bytes_equal"], spec


def test_hf_train_loop_mesh_route(parity_report):
    """make_fl_train_loop under constrain_params + mesh ShardCtx (the
    resolve_attn_backend sharded path) agrees with the unsharded loop."""
    for spec in ("1x1", "2x2"):
        assert parity_report["meshes"][spec]["hf_loop_allclose"], spec


# -- in-process pieces -------------------------------------------------------

def _toy_space(n=3000, seed=0):
    key = jax.random.key(seed)
    params = {"w": jax.random.normal(key, (64, 128), jnp.float32),
              "b": jnp.zeros((128,), jnp.float32)}
    return params, random_mask(params, density=0.3, seed=seed,
                               balanced=False)


def test_gradip_backend_parity():
    """Pallas blocked reduction vs jnp dot: same trajectories (float tol —
    different summation orders), same shapes."""
    _, space = _toy_space()
    T = 7
    keys = round_keys(0, 0, T)
    gs = jnp.linspace(-1.0, 1.0, T, dtype=jnp.float32)
    gp = jax.random.normal(jax.random.key(9), (space.n,), jnp.float32)
    ip_p, n_p, c_p = gradip_trajectory(space, keys, gs, gp,
                                       backend="pallas")
    ip_r, n_r, c_r = gradip_trajectory(space, keys, gs, gp, backend="ref")
    assert ip_p.shape == ip_r.shape == (T,)
    np.testing.assert_allclose(np.asarray(ip_p), np.asarray(ip_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(n_p), np.asarray(n_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(c_p), np.asarray(c_r),
                               rtol=1e-4, atol=1e-6)


def test_gradip_auto_resolution():
    """auto -> pallas for concrete single-device vectors, ref for tracers."""
    gp = jnp.ones((256,), jnp.float32)
    assert _resolve_gradip_backend(None, gp) == "pallas"
    assert _resolve_gradip_backend("auto", np.ones((4,), np.float32)) \
        == "pallas"
    seen = {}

    def f(v):
        seen["route"] = _resolve_gradip_backend("auto", v)
        return v

    jax.jit(f)(gp)
    assert seen["route"] == "ref"
    with pytest.raises(ValueError):
        _resolve_gradip_backend("bogus", gp)


def test_parse_mesh_spec():
    from repro.launch.mesh import parse_mesh_spec
    mc = parse_mesh_spec("2x2")
    assert (mc.data, mc.model, mc.pods) == (2, 2, 1)
    assert mc.n_devices == 4 and mc.batch_axes == ("data",)
    mc3 = parse_mesh_spec("2x16x16")
    assert (mc3.pods, mc3.data, mc3.model) == (2, 16, 16)
    assert mc3.batch_axes == ("pod", "data")
    assert parse_mesh_spec("single").n_devices == 256
    assert parse_mesh_spec("multi").n_devices == 512
    with pytest.raises(ValueError):
        parse_mesh_spec("2x")
    with pytest.raises(ValueError):
        parse_mesh_spec("weird")


def test_fl_plan_specs_without_devices():
    """FLShardPlan spec logic that needs no real mesh devices."""
    from repro.configs.base import MeshConfig
    from repro.sharding.fl import FLShardPlan
    P = jax.sharding.PartitionSpec
    mc = MeshConfig(data=2, model=2)
    plan = FLShardPlan.__new__(FLShardPlan)
    object.__setattr__(plan, "mesh", None)
    object.__setattr__(plan, "mesh_cfg", mc)
    object.__setattr__(plan, "rule", "fsdp")
    assert plan.batch_axes == ("data", "model") and plan.dp == 4
    assert plan.client_batch_spec(8, 3) == P(("data", "model"), None, None)
    assert plan.client_batch_spec(7, 2) == P(None, None)  # ragged fleet
    object.__setattr__(plan, "rule", "tp")
    assert plan.batch_axes == ("data",) and plan.dp == 2
    with pytest.raises(ValueError):
        FLShardPlan(None, mc, rule="bogus")
