"""Kernel-dispatch layer: flat backing + pallas-vs-ref backend parity.

The fused flat route (core/dispatch.py -> kernels/zo_update.py) must be a
drop-in replacement for the pytree ``space.add`` reference route on every
hot-path entry point, including multi-direction estimation (n_dirs > 1) and
flat sizes that are not multiples of the kernels' block_r * 128 tile
(the ops.py padding path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DenseSpace, LoRASpace, get_backing, random_mask,
                        resolve_backend, round_keys)
from repro.core.fl_step import make_fl_round_step, make_fl_train_step
from repro.core.virtual_path import reconstruct_delta
from repro.core.zo import local_step, make_local_run, projected_gradient


def vec_params(key, sizes=((24,), (4, 6))):
    ks = jax.random.split(key, len(sizes))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, sizes))}


def total_size(params):
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


def vec_loss(params, batch):
    # mean keeps the loss O(1) at every size: (l+ - l-) / 2eps amplifies f32
    # rounding of the loss ~500x, so parity needs a well-conditioned problem
    v = jnp.concatenate([l.reshape(-1) for l in jax.tree.leaves(params)])
    return 0.5 * jnp.mean((v - batch["target"]) ** 2)


def vec_per_example(params, batch):
    v = jnp.concatenate([l.reshape(-1) for l in jax.tree.leaves(params)])
    return 0.5 * jnp.mean((v[None, :] - batch["target"]) ** 2, axis=-1)


# --------------------------------------------------------- flat backing -----

def test_flatten_unflatten_roundtrip_is_exact():
    params = vec_params(jax.random.key(0), sizes=((7, 11), (33,), ()))
    space = random_mask(params, density=0.3, seed=1)
    b = get_backing(space, params)
    assert b.n_flat == total_size(params)
    # through the space-level flat API (delegates to the cached backing)
    out = space.unflatten(space.flatten(params), params)
    for a, c in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_full_coverage_mask_local_run_shapes():
    """density=1.0 makes a MaskedSpace whose flat backing is the identity;
    the pallas route must still return [n]-shaped deltas (regression: the
    identity restrict once leaked the tile-padded [n_pad] vector)."""
    params = vec_params(jax.random.key(30))
    space = random_mask(params, density=1.0, seed=0)
    b = get_backing(space, params)
    assert b.identity and b.n_pad > space.n
    keys = round_keys(0, 0, 2)
    batches = {"target": jax.random.normal(jax.random.key(31),
                                           (2, total_size(params)))}
    run = jax.jit(make_local_run(vec_loss, space, 1e-3, 1e-2,
                                 backend="pallas"))
    d_T, gs = run(params, keys, batches, jnp.zeros((space.n,), jnp.float32))
    assert d_T.shape == (space.n,)
    d_srv = reconstruct_delta(space, keys, gs, 1e-2)
    np.testing.assert_allclose(np.asarray(d_T), np.asarray(d_srv), atol=1e-6)


def test_expand_restrict_roundtrip_and_mask():
    params = vec_params(jax.random.key(1))
    space = random_mask(params, density=0.25, seed=2)
    b = get_backing(space, params)
    v = jax.random.normal(jax.random.key(3), (space.n,))
    dense = b.expand(v)
    np.testing.assert_array_equal(np.asarray(b.restrict(dense)),
                                  np.asarray(v))
    assert float(np.sum(b.mask)) == space.n
    # expand only writes the masked coordinates
    assert int((np.asarray(dense) != 0).sum()) <= space.n


def test_dense_space_backing_is_identity():
    params = vec_params(jax.random.key(2))
    space = DenseSpace(params)
    b = get_backing(space, params)
    assert b.identity
    v = jax.random.normal(jax.random.key(4), (space.n,))
    dense = np.asarray(b.expand(v))
    np.testing.assert_array_equal(dense[:space.n], np.asarray(v))
    # the tile-alignment tail is zero so kernels never see garbage
    assert not dense[space.n:].any()


def test_lora_space_backing_covers_only_lora_leaves():
    params = {"w": jnp.ones((4, 4)), "lora_a": jnp.ones((4, 2)),
              "lora_b": jnp.ones((2, 4))}
    space = LoRASpace(params)
    b = get_backing(space, params)
    assert space.n == 16 and b.n_flat == 32
    dense = b.expand(jnp.ones((space.n,)))
    # the w block (leaf order is sorted keys: lora_a, lora_b, w) stays zero
    assert float(jnp.sum(dense)) == 16.0
    np.testing.assert_array_equal(np.asarray(b.restrict(dense)),
                                  np.ones(16, np.float32))


def test_backing_cached_per_layout():
    params = vec_params(jax.random.key(5))
    space = random_mask(params, density=0.5, seed=0)
    assert get_backing(space, params) is get_backing(space, params)


# ----------------------------------------------------- backend resolution ---

def test_auto_prefers_pallas_and_falls_back():
    params = vec_params(jax.random.key(6))
    space = random_mask(params, density=0.5, seed=0)
    b = get_backing(space, params)
    assert resolve_backend(None, b) == "pallas"
    assert resolve_backend("auto", b) == "pallas"
    assert resolve_backend("ref", b) == "ref"
    # sharded steps never take the flat route (GSPMD reshape hazard)
    assert resolve_backend("auto", b, sharded=True) == "ref"
    with pytest.raises(ValueError):
        resolve_backend("cuda", b)


def test_auto_falls_back_on_mixed_dtypes():
    params = {"a": jnp.ones((8,), jnp.float32),
              "b": jnp.ones((8,), jnp.bfloat16)}
    space = random_mask(params, density=0.5, seed=0)
    b = get_backing(space, params)
    assert not b.supported
    assert resolve_backend("auto", b) == "ref"


# ------------------------------------------------------- step parity --------

# sizes chosen to exercise the (R, 128) padding path: sub-lane (48),
# non-multiple-of-128 (5000), and > one 256*128 block (40_000)
PARITY_SIZES = [((24,), (4, 6)), ((40, 125), (3,)), ((163, 245), (65,))]


@pytest.mark.parametrize("sizes", PARITY_SIZES)
@pytest.mark.parametrize("n_dirs", [1, 3])
def test_local_step_parity(sizes, n_dirs):
    params = vec_params(jax.random.key(7), sizes=sizes)
    n_total = total_size(params)
    space = random_mask(params, density=0.2, seed=3)
    batch = {"target": jax.random.normal(jax.random.key(8), (n_total,))}
    delta = 0.01 * jax.random.normal(jax.random.key(9), (space.n,))
    out = {}
    for be in ("ref", "pallas"):
        out[be] = local_step(vec_loss, params, space, delta,
                             jax.random.key(10), 1e-3, 1e-2, batch,
                             n_dirs=n_dirs, backend=be)
    np.testing.assert_allclose(np.asarray(out["ref"][0]),
                               np.asarray(out["pallas"][0]),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out["ref"][1]),
                               np.asarray(out["pallas"][1]),
                               rtol=1e-2, atol=5e-3)


@pytest.mark.parametrize("n_dirs", [1, 2])
def test_local_run_parity_and_virtual_path_exactness(n_dirs):
    """The pallas T-step loop matches ref AND stays exactly reconstructible
    from the uploaded scalars (paper Alg. 2 step 2)."""
    T, lr = 4, 1e-2
    params = vec_params(jax.random.key(11))
    space = random_mask(params, density=0.4, seed=4)
    keys = round_keys(5, 0, T)
    batches = {"target": jax.random.normal(jax.random.key(12),
                                           (T, total_size(params)))}
    delta0 = jnp.zeros((space.n,), jnp.float32)
    runs = {be: jax.jit(make_local_run(vec_loss, space, 1e-3, lr,
                                       n_dirs=n_dirs, backend=be))
            for be in ("ref", "pallas")}
    d_ref, g_ref = runs["ref"](params, keys, batches, delta0)
    d_pal, g_pal = runs["pallas"](params, keys, batches, delta0)
    if n_dirs > 1:
        assert g_pal.shape == (T, n_dirs)
    np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_pal),
                               rtol=1e-2, atol=5e-3)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_pal),
                               rtol=1e-3, atol=1e-4)
    # exactness vs the server-side replay of the *pallas* scalars
    d_srv = reconstruct_delta(space, keys, g_pal, lr)
    np.testing.assert_allclose(np.asarray(d_pal), np.asarray(d_srv),
                               atol=1e-6)


def test_full_coverage_permuted_mask_is_not_identity():
    """A mask covering every coordinate in a *permuted* order must not take
    the identity shortcut — expand/restrict have to honor the index order
    (regression: n == N alone used to be treated as identity)."""
    from repro.core import MaskedSpace

    params = {"a": jnp.arange(8.0), "b": jnp.arange(6.0).reshape(2, 3)}
    perm_a = jnp.asarray([3, 0, 7, 1, 5, 2, 6, 4], jnp.int32)
    perm_b = jnp.asarray([5, 2, 0, 4, 1, 3], jnp.int32)
    space = MaskedSpace({"a": perm_a, "b": perm_b})
    b = get_backing(space, params)
    assert space.n == b.n_flat and not b.identity
    v = jnp.arange(1.0, space.n + 1.0)
    dense = b.expand(v)
    # value v[i] must land at the permuted position, not position i
    np.testing.assert_array_equal(np.asarray(dense)[np.asarray(perm_a)],
                                  np.asarray(v[:8]))
    np.testing.assert_array_equal(np.asarray(b.restrict(dense)),
                                  np.asarray(v))
    batch = {"target": jnp.zeros(space.n)}
    out = {be: local_step(vec_loss, params, space, jnp.zeros((space.n,)),
                          jax.random.key(0), 1e-3, 1e-2, batch, backend=be)
           for be in ("ref", "pallas")}
    np.testing.assert_allclose(np.asarray(out["ref"][0]),
                               np.asarray(out["pallas"][0]),
                               rtol=1e-3, atol=1e-4)


def test_projected_gradient_parity():
    params = vec_params(jax.random.key(13))
    space = DenseSpace(params)
    batch = {"target": jnp.zeros(total_size(params))}
    z = space.sample_z(jax.random.key(14))
    delta = jnp.zeros((space.n,))
    g_ref = projected_gradient(vec_loss, params, space, delta, z, 1e-4,
                               batch, backend="ref")
    g_pal = projected_gradient(vec_loss, params, space, delta, z, 1e-4,
                               batch, backend="pallas")
    assert abs(float(g_ref) - float(g_pal)) < 1e-3 * max(1.0,
                                                         abs(float(g_ref)))


@pytest.mark.parametrize("sizes", PARITY_SIZES)
def test_fl_train_step_parity(sizes):
    n_clients, bs = 4, 2
    params = vec_params(jax.random.key(15), sizes=sizes)
    space = random_mask(params, density=0.2, seed=6)
    batch = {"target": jax.random.normal(jax.random.key(16),
                                         (n_clients * bs,
                                          total_size(params)))}
    out = {}
    for be in ("ref", "pallas"):
        step = jax.jit(make_fl_train_step(vec_per_example, space, eps=1e-3,
                                          lr=1e-2, n_clients=n_clients,
                                          backend=be))
        out[be] = step(params, jax.random.key(17), batch)
    np.testing.assert_allclose(np.asarray(out["ref"][1]),
                               np.asarray(out["pallas"][1]),
                               rtol=1e-2, atol=5e-3)
    for a, b in zip(jax.tree.leaves(out["ref"][0]),
                    jax.tree.leaves(out["pallas"][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
    assert np.allclose(float(out["ref"][2]["loss"]),
                       float(out["pallas"][2]["loss"]), rtol=1e-4)


@pytest.mark.parametrize("backend,stack_forwards",
                         [("pallas", True), ("pallas", False),
                          ("pallas", None), ("ref", None)])
def test_fl_train_loop_parity(backend, stack_forwards):
    """The scanned burst == folding make_fl_train_step, on the ref-route
    scan (the bench's naive baseline) and both fused forward strategies
    (stacked vmap / sequential) plus the auto pick."""
    from repro.core.fl_step import make_fl_train_loop

    n_clients, bs, n_steps = 4, 2, 3
    params = vec_params(jax.random.key(40), sizes=((48,), (8, 12)))
    space = random_mask(params, density=0.2, seed=41)
    batches = {"target": jax.random.normal(
        jax.random.key(42), (n_steps, n_clients * bs, total_size(params)))}
    kw = dict(eps=1e-3, lr=1e-2, n_clients=n_clients)
    key = jax.random.key(43)

    loop = jax.jit(make_fl_train_loop(vec_per_example, space, n_steps=n_steps,
                                      backend=backend,
                                      stack_forwards=stack_forwards, **kw))
    p_loop, gs_loop, m_loop = loop(params, key, batches)

    # fold the single-step factory over the same keys/batches
    step = jax.jit(make_fl_train_step(vec_per_example, space, backend="ref",
                                      **kw))
    p, gs = params, []
    for t, k in enumerate(jax.random.split(key, n_steps)):
        p, g_cl, m = step(p, k, jax.tree.map(lambda x: x[t], batches))
        gs.append(np.asarray(g_cl))
    np.testing.assert_allclose(np.asarray(gs_loop), np.stack(gs),
                               rtol=1e-2, atol=5e-3)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p_loop)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)
    assert np.allclose(float(m["loss"]), float(m_loop["loss"]), rtol=1e-4)


def test_fl_round_step_parity_vmapped_clients():
    T, K = 3, 2
    params = vec_params(jax.random.key(18))
    space = random_mask(params, density=0.3, seed=7)
    keys = round_keys(8, 0, T)
    batches = {"target": jax.random.normal(jax.random.key(19),
                                           (K, T, total_size(params)))}
    out = {}
    for be in ("ref", "pallas"):
        step = jax.jit(make_fl_round_step(vec_loss, space, eps=1e-3, lr=1e-2,
                                          T=T, backend=be))
        out[be] = step(params, keys, batches)
    np.testing.assert_allclose(np.asarray(out["ref"][1]),
                               np.asarray(out["pallas"][1]),
                               rtol=1e-2, atol=5e-3)
    for a, b in zip(jax.tree.leaves(out["ref"][0]),
                    jax.tree.leaves(out["pallas"][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)


def test_pallas_updates_only_masked_coords():
    """Off-mask coordinates survive the fused update bitwise."""
    params = vec_params(jax.random.key(20))
    space = random_mask(params, density=0.1, seed=9)
    b = get_backing(space, params)
    batch = {"target": jnp.zeros(total_size(params))}
    delta, _ = local_step(vec_loss, params, space,
                          jnp.zeros((space.n,)), jax.random.key(21),
                          1e-3, 1e-2, batch, backend="pallas")
    step = jax.jit(make_fl_train_step(vec_per_example, space, eps=1e-3,
                                      lr=1e-2, n_clients=1,
                                      backend="pallas"))
    new_params, _, _ = step(params, jax.random.key(22),
                            {"target": jnp.zeros((2, total_size(params)))})
    w0 = np.asarray(b.flatten(params))
    w1 = np.asarray(b.flatten(new_params))
    off = np.asarray(b.mask) == 0.0
    np.testing.assert_array_equal(w0[off], w1[off])
