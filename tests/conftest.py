import os
import sys

# Tests run single-device (the dry-run forces 512 host devices in its own
# process; see launch/dryrun.py). Keep CPU determinism reasonable.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
