"""Serving consistency: prefill + decode must reproduce the training forward
exactly; the batched engine runs end to end."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.shapes import TRAIN_4K
from repro.models import Model, concrete_inputs
from repro.serving import ServeEngine, generate

S = 12


@pytest.mark.parametrize("name", list_archs())
def test_prefill_decode_matches_forward(name):
    cfg = get_config(name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = concrete_inputs(cfg, TRAIN_4K.reduced(seq_len=S, global_batch=2))
    logits_full, _ = model.forward(params, batch)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    extra = cfg.n_patches if cfg.frontend == "vision_stub" else 0
    lp, cache = model.prefill(params, pre, S_max=S + 4 + extra)
    ld, cache2 = model.decode_step(params, batch["tokens"][:, S - 1], cache)

    np.testing.assert_allclose(lp, logits_full[:, S - 2], atol=2e-4)
    np.testing.assert_allclose(ld, logits_full[:, S - 1], atol=2e-4)
    np.testing.assert_array_equal(np.asarray(cache2["pos"]),
                                  np.asarray(cache["pos"]) + 1)


def test_causality():
    """Dropping the last token must not change earlier logits (catches
    cross-token leaks, e.g. MoE capacity collisions)."""
    for name in list_archs():
        cfg = get_config(name).reduced()
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        batch = concrete_inputs(cfg,
                                TRAIN_4K.reduced(seq_len=S, global_batch=2))
        l1, _ = model.forward(params, batch)
        b2 = dict(batch)
        b2["tokens"] = batch["tokens"][:, :S - 1]
        l2, _ = model.forward(params, b2)
        np.testing.assert_allclose(l2[:, :S - 2], l1[:, :S - 2], atol=2e-4,
                                   err_msg=name)


def test_generate_greedy_deterministic():
    cfg = get_config("qwen3-4b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = concrete_inputs(cfg, TRAIN_4K.reduced(seq_len=8, global_batch=2))
    out1 = generate(model, params, batch, max_new_tokens=5)
    out2 = generate(model, params, batch, max_new_tokens=5)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(out1, out2)
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()


def test_serve_engine_batching():
    cfg = get_config("qwen2-7b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, max_batch=3, bucket=8)
    rng = np.random.default_rng(0)
    lens = [5, 8, 3, 7, 6]
    for L in lens:
        eng.submit(rng.integers(0, cfg.vocab, size=L), max_new_tokens=4)
    outs = eng.flush()
    assert len(outs) == len(lens)
    assert all(o.shape == (4,) for o in outs)
