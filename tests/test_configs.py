import pytest

from repro.configs import ASSIGNED, SHAPES, get_config, get_shape

EXPECTED = {
    "xlstm-350m": dict(n_layers=24, d_model=1024, n_heads=4, d_ff=0,
                       vocab=50304),
    "whisper-small": dict(n_layers=12, d_model=768, n_heads=12, d_ff=3072,
                          vocab=51865),
    "qwen3-4b": dict(n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
                     d_ff=9728, vocab=151936),
    "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                            n_kv_heads=8, vocab=163840),
    "phi3.5-moe-42b-a6.6b": dict(n_layers=32, d_model=4096, n_heads=32,
                                 n_kv_heads=8, vocab=32064),
    "qwen2-7b": dict(n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
                     d_ff=18944, vocab=152064),
    "chatglm3-6b": dict(n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
                        d_ff=13696, vocab=65024),
    "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64,
                                 n_kv_heads=8, vocab=65536),
    "gemma2-27b": dict(n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
                       d_ff=36864, vocab=256000),
    "pixtral-12b": dict(n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
                        d_ff=14336, vocab=131072),
}


def test_all_assigned_present():
    assert set(EXPECTED) == set(ASSIGNED)


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_exact_config_values(name):
    cfg = get_config(name)
    for k, v in EXPECTED[name].items():
        assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)


def test_moe_configs():
    assert get_config("kimi-k2-1t-a32b").moe.n_experts == 384
    assert get_config("kimi-k2-1t-a32b").moe.top_k == 8
    assert get_config("phi3.5-moe-42b-a6.6b").moe.n_experts == 16
    assert get_config("jamba-1.5-large-398b").moe.top_k == 2


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_reduced_limits(name):
    r = get_config(name).reduced()
    assert r.n_layers <= 2 * r.period and r.d_model <= 512
    if r.moe is not None:
        assert r.moe.n_experts <= 4
    assert r.n_layers % r.period == 0


def test_param_counts_match_scale():
    """Sanity: configured sizes land near their nameplate parameter counts."""
    from repro.models import active_param_count, param_count
    assert 0.9e12 < param_count(get_config("kimi-k2-1t-a32b")) < 1.15e12
    assert 25e9 < active_param_count(get_config("kimi-k2-1t-a32b")) < 40e9
    assert 330e9 < param_count(get_config("jamba-1.5-large-398b")) < 430e9
    assert 6e9 < param_count(get_config("qwen2-7b")) < 9e9
    assert 24e9 < param_count(get_config("gemma2-27b")) < 30e9
    assert 0.25e9 < param_count(get_config("xlstm-350m")) < 0.5e9


def test_shapes_registry():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].kind == "decode"
    assert get_shape("long_500k").seq_len == 524_288


def test_long_context_gate():
    assert get_config("xlstm-350m").supports_long_context
    assert get_config("jamba-1.5-large-398b").supports_long_context
    assert get_config("gemma2-27b").supports_long_context
    for n in ("qwen3-4b", "qwen2-7b", "chatglm3-6b", "pixtral-12b",
              "whisper-small", "kimi-k2-1t-a32b", "phi3.5-moe-42b-a6.6b"):
        assert not get_config(n).supports_long_context, n
