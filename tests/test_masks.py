"""Mask-selection tests (paper §2.1: sensitivity / magnitude / random)."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (abstract_mask, magnitude_mask, random_mask,
                        sensitivity_mask, sensitivity_scores)
from repro.core.masks import _global_topk_indices


def test_global_topk_selects_highest_scores():
    scores = {"a": jnp.asarray([0.1, 5.0, 0.2]),
              "b": jnp.asarray([[3.0, 0.0], [4.0, 0.05]])}
    idx = _global_topk_indices(scores, density=3 / 7)
    # top-3 of [0.1, 5, 0.2, 3, 0, 4, 0.05] -> a[1], b[0,0], b[1,0]
    assert list(np.asarray(idx["a"])) == [1]
    assert sorted(np.asarray(idx["b"]).tolist()) == [0, 2]


def test_magnitude_mask_picks_largest_weights():
    params = {"w": jnp.asarray([-10.0, 0.1, 3.0, -5.0])}
    sp = magnitude_mask(params, density=0.5)
    assert sorted(np.asarray(sp.idx_tree["w"]).tolist()) == [0, 3]


@hypothesis.given(density=st.sampled_from([1e-3, 1e-2, 0.1, 0.5]))
@hypothesis.settings(max_examples=8, deadline=None)
def test_density_respected(density):
    params = {"w": jnp.zeros((100, 40)), "b": jnp.zeros((77,))}
    sp = random_mask(params, density=density, seed=0, balanced=False)
    total = 4077
    assert sp.n == max(1, round(total * density))


def test_sensitivity_mask_targets_sensitive_coords():
    """Quadratic with per-coordinate curvature: sensitivity (avg grad^2) must
    pick the high-curvature coordinates."""
    scale = jnp.concatenate([jnp.full((10,), 10.0), jnp.full((30,), 0.1)])
    params = {"w": jnp.ones((40,))}

    def loss(p, batch):
        return 0.5 * jnp.sum(scale * (p["w"] - batch["t"]) ** 2)

    batches = [{"t": jax.random.normal(jax.random.key(i), (40,)) + 2.0}
               for i in range(4)]
    sp = sensitivity_mask(loss, params, batches, density=0.25)
    chosen = set(np.asarray(sp.idx_tree["w"]).tolist())
    assert chosen == set(range(10)), chosen


def test_sensitivity_scores_average():
    params = {"w": jnp.zeros((3,))}
    loss = lambda p, b: jnp.sum(p["w"] * b["x"])
    batches = [{"x": jnp.asarray([1.0, 0.0, 2.0])},
               {"x": jnp.asarray([3.0, 0.0, 0.0])}]
    sc = sensitivity_scores(loss, params, batches)
    np.testing.assert_allclose(sc["w"], [(1 + 9) / 2, 0.0, 2.0], atol=1e-6)


def test_abstract_mask_clamps_density():
    ap = {"w": jax.ShapeDtypeStruct((1000, 1000), jnp.bfloat16)}
    idx, eff = abstract_mask(ap, density=1e-3, max_coords=100)
    assert eff <= 100 / 1e6
    assert idx["w"].shape[0] <= 100
    idx2, eff2 = abstract_mask(ap, density=1e-4)
    assert eff2 == 1e-4 and idx2["w"].shape[0] == 100


def test_balanced_random_mask_covers_every_leaf():
    params = {"a": jnp.zeros((64, 64)), "b": jnp.zeros((4096,)),
              "c": jnp.zeros((8, 8, 8))}
    sp = random_mask(params, density=0.01, seed=3, balanced=True)
    for leaf in jax.tree.leaves(sp.idx_tree):
        assert leaf.shape[0] >= 1
        assert len(set(np.asarray(leaf).tolist())) == leaf.shape[0]  # unique
