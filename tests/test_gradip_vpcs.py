"""GradIP (Definition 2.3) and VPCS (Algorithm 1) unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import (DenseSpace, analyze_trajectory, gradip_trajectory,
                        pretrain_gradient_vec, round_keys, select_clients)


def test_gradip_matches_manual_inner_product():
    params = {"w": jnp.zeros((32,))}
    space = DenseSpace(params)
    keys = round_keys(0, 0, 4)
    gs = jnp.asarray([0.5, -1.0, 2.0, 0.0])
    gp = jax.random.normal(jax.random.key(9), (space.n,))
    ips, norms, coss = gradip_trajectory(space, keys, gs, gp)
    for t in range(4):
        z = space.sample_z(keys[t])
        manual = float(gs[t] * jnp.dot(gp, z))
        assert abs(float(ips[t]) - manual) < 1e-5
    assert float(ips[3]) == 0.0 and float(norms[3]) == 0.0


def test_pretrain_gradient_vec():
    params = {"w": jnp.ones((8,))}
    space = DenseSpace(params)
    loss = lambda p, b: jnp.sum(p["w"] * b["x"])
    batches = [{"x": jnp.ones((8,))}, {"x": 3 * jnp.ones((8,))}]
    gp = pretrain_gradient_vec(loss, params, space, batches)
    np.testing.assert_allclose(gp, 2.0 * np.ones(8), atol=1e-6)


def _fl(**kw):
    base = dict(vp_init_steps=20, vp_later_steps=20, vp_sigma=0.5,
                vp_rho_later=5.0, vp_rho_quie=0.5)
    base.update(kw)
    return FLConfig(**base)


def test_vpcs_flags_decaying_trajectory():
    t = np.arange(100)
    decaying = 10.0 * np.exp(-t / 10.0)          # extreme Non-IID signature
    oscillating = 5.0 + np.sin(t) * 2.0          # IID signature
    fl = _fl()
    r_bad = analyze_trajectory(decaying, fl)
    r_good = analyze_trajectory(oscillating, fl)
    assert r_bad.flagged and r_bad.rho_later > fl.vp_rho_later
    assert not r_good.flagged


def test_vpcs_quiescence_criterion():
    """A trajectory that collapses below sigma late in training is flagged by
    the quiescent-step ratio even if the mean ratio is moderate."""
    t = np.arange(100)
    traj = np.where(t < 70, 2.0, 0.01)
    fl = _fl(vp_rho_later=1e9)  # disable the ratio criterion
    r = analyze_trajectory(traj, fl)
    assert r.rho_quie == 1.0 and r.flagged


def test_select_clients():
    t = np.arange(100)
    trajs = [10 * np.exp(-t / 8), 4 + np.sin(t), 8 * np.exp(-t / 12)]
    results, flagged = select_clients(trajs, _fl())
    assert flagged == [0, 2]
