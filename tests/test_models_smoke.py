"""Per-architecture smoke tests (REQUIRED): instantiate the reduced variant
of each assigned arch, run one forward and one first-order train step on CPU,
assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.configs.shapes import TRAIN_4K
from repro.models import Model, concrete_inputs
from repro.models.transformer import lm_loss
from repro.train import make_train_step

SHAPE = TRAIN_4K.reduced(seq_len=16, global_batch=2)


@pytest.mark.parametrize("name", list_archs())
def test_forward_and_train_step(name):
    cfg = get_config(name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = concrete_inputs(cfg, SHAPE)

    logits, aux = model.forward(params, batch)
    assert logits.shape == (SHAPE.global_batch, SHAPE.seq_len, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), f"{name}: NaN logits"
    assert not bool(jnp.isnan(aux)), f"{name}: NaN aux loss"

    init, step = make_train_step(
        lambda p, b: lm_loss(p, b, cfg), optimizer="sgd", lr=1e-2)
    opt = init(params)
    p2, opt, loss = step(params, opt, batch)
    assert jnp.isfinite(loss), f"{name}: non-finite loss"
    l2 = lm_loss(p2, batch, cfg)
    assert jnp.isfinite(l2)
    # one SGD step on the same batch should not increase loss materially
    assert float(l2) <= float(loss) + 1e-3, (name, float(loss), float(l2))


@pytest.mark.parametrize("name", list_archs())
def test_zo_step_runs(name):
    """The paper's sparse-ZO step runs on every assigned architecture."""
    from repro.core import random_mask
    from repro.core.zo import local_step

    cfg = get_config(name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    batch = concrete_inputs(cfg, SHAPE)
    space = random_mask(params, density=1e-3, seed=0)
    loss_fn = lambda p, b: lm_loss(p, b, cfg)
    delta = jnp.zeros((space.n,), jnp.float32)
    delta2, g = local_step(loss_fn, params, space, delta, jax.random.key(2),
                           1e-3, 1e-2, batch)
    assert jnp.isfinite(g)
    assert delta2.shape == (space.n,)
    assert not bool(jnp.isnan(delta2).any())
