"""Pallas kernel sweeps: shapes x dtypes, allclose vs the ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]
SIZES = [1024, 4096, 40_000, 262_144]


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dual_perturb_sweep(n, dtype):
    key = jax.random.key(n)
    w = jax.random.normal(key, (n,), dtype)
    z = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    m = (jax.random.uniform(jax.random.fold_in(key, 2), (n,)) < 0.05
         ).astype(jnp.float32)
    p, mi = ops.zo_dual_perturb_flat(w, z, m, 1e-3)
    rp, rm = ref.dual_perturb_ref(w, z, m, 1e-3)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(p, np.float32),
                               np.asarray(rp, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(mi, np.float32),
                               np.asarray(rm, np.float32), atol=tol)


@pytest.mark.parametrize("n", SIZES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_update_sweep(n, dtype):
    key = jax.random.key(n + 7)
    w = jax.random.normal(key, (n,), dtype)
    z = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float32)
    m = (jax.random.uniform(jax.random.fold_in(key, 2), (n,)) < 0.05
         ).astype(jnp.float32)
    u = ops.zo_fused_update_flat(w, z, m, -0.05)
    ru = ref.fused_update_ref(w, z, m, -0.05)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(u, np.float32),
                               np.asarray(ru, np.float32), atol=tol)


@pytest.mark.parametrize("kernel", ["dual_perturb", "fused_update"])
def test_zo_kernels_multiblock_grid(kernel):
    """Pin block_r so interpret mode runs a real multi-step grid (the
    default collapses CPU runs to one grid step; this keeps the BlockSpec
    index-map path covered off-TPU)."""
    n = 8192  # R = 64 rows -> grid=(8,) at block_r=8
    key = jax.random.key(n)
    w = jax.random.normal(key, (n,))
    z = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    m = (jax.random.uniform(jax.random.fold_in(key, 2), (n,)) < 0.3
         ).astype(jnp.float32)
    if kernel == "dual_perturb":
        p, mi = ops.zo_dual_perturb_flat(w, z, m, 1e-3, block_r=8)
        rp, rm = ref.dual_perturb_ref(w, z, m, 1e-3)
        np.testing.assert_allclose(np.asarray(p), np.asarray(rp), atol=1e-6)
        np.testing.assert_allclose(np.asarray(mi), np.asarray(rm), atol=1e-6)
    else:
        u = ops.zo_fused_update_flat(w, z * m, None, -0.05, block_r=8)
        ru = ref.fused_update_ref(w, z, m, -0.05)
        np.testing.assert_allclose(np.asarray(u), np.asarray(ru), atol=1e-6)


@pytest.mark.parametrize("n", SIZES)
def test_gradip_sweep(n):
    key = jax.random.key(n + 13)
    gp = jax.random.normal(key, (n,))
    z = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    out = ops.gradip_flat(gp, z, 1.7)
    want = ref.gradip_reduce_ref(gp, z, 1.7)
    assert abs(float(out) - float(want)) < 5e-4 * max(1.0, abs(float(want)))


@pytest.mark.parametrize("B,KVH,G,dh,S,L", [
    (1, 1, 1, 64, 512, 512),
    (2, 2, 4, 64, 1024, 700),
    (2, 4, 2, 128, 2048, 1),
    (1, 8, 8, 128, 1024, 1023),
])
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_decode_sweep(B, KVH, G, dh, S, L, dtype):
    key = jax.random.key(B * S)
    q = jax.random.normal(key, (B, KVH, G, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KVH, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KVH, dh), dtype)
    out = ops.flash_decode(q, k, v, L, block_s=256)
    want = ref.decode_attention_ref(q, k, v, L)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_flash_decode_matches_model_attention():
    """The kernel agrees with the model's decode attention math (GQA)."""
    from repro.models.layers import gqa_attention
    from repro.configs.tiny import TINY
    B, KV, G, hd, S, L = 2, 2, 2, 32, 256, 100
    key = jax.random.key(0)
    q4 = jax.random.normal(key, (B, 1, KV * G, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    valid = (jnp.arange(S) < L)[None, None, :]
    want = gqa_attention(q4, k, v, valid, TINY)[:, 0]  # [B, H, hd]
    # kernel layout: [B, KVH, G, dh]; heads grouped kv-major (repeat semantics)
    qk = q4[:, 0].reshape(B, KV, G, hd)
    out = ops.flash_decode(qk, k, v, L, block_s=64)
    np.testing.assert_allclose(np.asarray(out.reshape(B, KV * G, hd)),
                               np.asarray(want.reshape(B, KV * G, hd)),
                               atol=2e-5)


# ------------------------------------------------------- mamba scan ---------
@pytest.mark.parametrize("B,S,E,N,eb,sb", [
    (1, 256, 128, 8, 128, 128),
    (2, 512, 256, 16, 128, 256),
    (1, 384, 128, 16, 64, 128),
    (2, 256, 512, 4, 256, 64),
])
def test_mamba_scan_sweep(B, S, E, N, eb, sb):
    from repro.kernels.mamba_scan import mamba_scan
    key = jax.random.key(B * 1000 + S)
    ks = jax.random.split(key, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, E))) * 0.1
    Bi = jax.random.normal(ks[1], (B, S, N))
    Ci = jax.random.normal(ks[2], (B, S, N))
    x = jax.random.normal(ks[3], (B, S, E))
    A = -jnp.exp(jax.random.normal(ks[4], (E, N)))
    y, h = mamba_scan(dt, Bi, Ci, x, A, e_block=eb, s_block=sb,
                      interpret=True)
    yr, hr = ref.mamba_scan_ref(dt, Bi, Ci, x, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4)


def test_mamba_kernel_mode_matches_scan_mode():
    """mamba_forward(mode='kernel') == mode='scan' on a reduced config."""
    from repro.configs import get_config
    from repro.models import Model
    from repro.models.ssm import mamba_forward

    cfg = get_config("jamba-1.5-large-398b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    # find a mamba layer's params in the stacked tree
    stack = params["stack"]
    mamba_lp = None
    for k in stack:
        if "in_proj" in stack[k] and "A_log" in stack[k]:
            mamba_lp = jax.tree.map(lambda l: l[0], stack[k])
            break
    assert mamba_lp is not None, list(stack)
    x = jax.random.normal(jax.random.key(1), (2, 256, cfg.d_model))
    y_scan = mamba_forward(x, mamba_lp, cfg.ssm, mode="scan")
    y_kern = mamba_forward(x, mamba_lp, cfg.ssm, mode="kernel")
    np.testing.assert_allclose(np.asarray(y_scan, np.float32),
                               np.asarray(y_kern, np.float32),
                               atol=5e-3, rtol=5e-3)
