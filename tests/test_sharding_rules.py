"""Sharding-rule invariants (no devices needed — pure spec logic)."""
import jax
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.configs.base import MeshConfig
from repro.configs.shapes import get_shape
from repro.models.decode import abstract_cache
from repro.models.init import abstract_params
from repro.sharding.rules import cache_specs, fsdp_only_specs, param_specs

P = jax.sharding.PartitionSpec
MC = MeshConfig(data=16, model=16)
MC_POD = MeshConfig(data=16, model=16, pods=2)


def _axes_used(spec):
    out = []
    for s in spec:
        if s is None:
            continue
        out.extend([s] if isinstance(s, str) else list(s))
    return out


@pytest.mark.parametrize("mc", [MC, MC_POD], ids=["single", "multi"])
@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_param_specs_divisible_and_unique(arch, mc):
    """Every sharded dim is divisible by its axis product; no axis reused."""
    cfg = get_config(arch)
    ap = abstract_params(cfg)
    specs = param_specs(cfg, ap, mc)
    sizes = {"pod": mc.pods, "data": mc.data, "model": mc.model}
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(ap)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]):
        used = _axes_used(spec)
        assert len(used) == len(set(used)), (path, spec)
        for dim, s in zip(leaf.shape, spec):
            if s is None:
                continue
            n = int(np.prod([sizes[a] for a in
                             ([s] if isinstance(s, str) else s)]))
            assert dim % n == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_fsdp_only_specs_divisible(arch):
    cfg = get_config(arch)
    ap = abstract_params(cfg)
    specs = fsdp_only_specs(cfg, ap, MC)
    n = MC.n_devices
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(ap)[0],
            jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]):
        for dim, s in zip(leaf.shape, spec):
            if s is not None:
                assert dim % n == 0, (path, leaf.shape, spec)


def test_inference_specs_skip_fsdp():
    """train=False must not introduce batch-axis ('data') weight sharding."""
    cfg = get_config("qwen2-7b")
    ap = abstract_params(cfg)
    train = param_specs(cfg, ap, MC, train=True)
    infer = param_specs(cfg, ap, MC, train=False)
    t_axes = set(a for s in jax.tree_util.tree_leaves(
        train, is_leaf=lambda x: isinstance(x, P)) for a in _axes_used(s))
    i_axes = set(a for s in jax.tree_util.tree_leaves(
        infer, is_leaf=lambda x: isinstance(x, P)) for a in _axes_used(s))
    assert "data" in t_axes       # ZeRO-3 second axis active for training
    assert "data" not in i_axes   # §Perf pair 1 iteration 2


@pytest.mark.parametrize("arch", ["qwen2-7b", "chatglm3-6b", "gemma2-27b",
                                  "whisper-small"])
def test_decode_cache_never_shards_head_dim_first(arch):
    """§Perf pair 1: k/v cache prefers KV-heads or sequence over head_dim."""
    cfg = get_config(arch)
    shape = get_shape("decode_32k")
    ac = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    specs = cache_specs(cfg, ac, shape, MC)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        name = jax.tree_util.keystr(path)
        if "'k'" in name or "'v'" in name:
            # [n, B, W, KV, hd]: the hd slot may use 'model' only if
            # neither KV heads nor the sequence could take it
            if spec[4] is not None:
                assert spec[3] is None and spec[2] is None, (name, spec)
            # W = 32768 is divisible by 16, so hd must not be sharded here
            assert spec[4] is None, (name, spec)
