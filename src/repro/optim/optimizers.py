"""Minimal optimizer library (pytree-generic, jittable)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any = None
    nu: Any = None


def sgd(lr: float, momentum: float = 0.0):
    def init(params):
        mu = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
              if momentum else None)
        return OptState(jnp.zeros((), jnp.int32), mu=mu)

    def update(grads, state, params):
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                              state.mu, grads)
            upd = jax.tree.map(lambda m: -lr * m, mu)
            return upd, OptState(state.step + 1, mu=mu)
        upd = jax.tree.map(lambda g: -lr * g, grads)
        return upd, OptState(state.step + 1)

    return init, update


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    def init(params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)
        return OptState(jnp.zeros((), jnp.int32), mu=z(), nu=z())

    def update(grads, state, params):
        t = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        upd = jax.tree.map(
            lambda m, v: -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu)
        return upd, OptState(t, mu=mu, nu=nu)

    return init, update


def zo_sgd(lr: float, momentum: float = 0.0):
    """ZO-SGD over a flat sparse value vector (MEERKAT client optimizer)."""
    def init(n: int):
        mu = jnp.zeros((n,), jnp.float32) if momentum else None
        return OptState(jnp.zeros((), jnp.int32), mu=mu)

    def update(gz, state, _=None):
        """gz = g * z (the reconstructed sparse ZO gradient)."""
        if momentum:
            mu = momentum * state.mu + gz
            return -lr * mu, OptState(state.step + 1, mu=mu)
        return -lr * gz, OptState(state.step + 1)

    return init, update


def make_optimizer(name: str, lr: float, **kw):
    if name == "sgd":
        return sgd(lr, **kw)
    if name == "adam":
        return adam(lr, **kw)
    raise ValueError(name)
