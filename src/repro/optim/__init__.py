from repro.optim.optimizers import (adam, make_optimizer, sgd, zo_sgd,
                                    OptState)
from repro.optim.schedule import constant, cosine, warmup_cosine
