from repro.optim.optimizers import (OptState, adam, make_optimizer, sgd,
                                    zo_sgd)
from repro.optim.schedule import constant, cosine, warmup_cosine
