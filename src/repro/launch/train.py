"""Federated MEERKAT training driver (runnable end-to-end on CPU).

Runs sparse-ZO federated fine-tuning of any registered architecture's
*reduced* variant (or the tiny model) on the synthetic classification-LM
task family with Dirichlet Non-IID clients — Algorithm 2 end to end:
mask calibration from the C4-proxy corpus, per-round seed ladders, client
local ZO steps, server virtual-path reconstruction and aggregation, and
optional MEERKAT-VP calibration + early stopping.

``--mesh DxM`` runs every round sharded on a device mesh
(``sharding/fl.FLShardPlan``): parameters per ``sharding/rules.py``
(``--mesh-rule``, FSDP by default), the client axis over the mesh batch
axes.  On a CPU host the requested device count is forced via XLA_FLAGS
*before* jax is imported (pre-parsed from argv below); on TPU the same
spec maps onto the physical topology.

Examples:
  PYTHONPATH=src python -m repro.launch.train --rounds 40 --T 10
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --method full
  PYTHONPATH=src python -m repro.launch.train --vp --partition mixed
  PYTHONPATH=src python -m repro.launch.train --mesh 2x2 --rounds 4
  PYTHONPATH=src python -m repro.launch.train --checkpoint-dir runs/ckpt \\
      --checkpoint-every 1 --rounds 8   # then: same + --resume
  PYTHONPATH=src python -m repro.launch.train --drop-rate 0.2 --late-rate 0.1
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time


def _force_mesh_devices(argv):
    """If --mesh asks for more devices than the host platform exposes,
    force the count via XLA_FLAGS.  Runs before the first jax import —
    device count is fixed at backend initialization.  (Importing
    launch.mesh here is safe: it touches no jax device state.)"""
    spec = None
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            spec = argv[i + 1]
        elif a.startswith("--mesh="):
            spec = a.split("=", 1)[1]
    if not spec:
        return
    if "--xla_force_host_platform_device_count" in \
            os.environ.get("XLA_FLAGS", ""):
        return
    from repro.launch.mesh import host_device_flag, parse_mesh_spec
    try:
        n = parse_mesh_spec(spec).n_devices
    except ValueError:
        return  # argparse will reject the spec with a proper error
    if n > 1:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " " + host_device_flag(n)).strip()


_force_mesh_devices(sys.argv[1:])

import jax  # noqa: E402  (after the XLA_FLAGS pre-parse, by design)
import numpy as np  # noqa: E402

from repro.checkpoint.state import FINAL_NAME, LATEST_NAME
from repro.configs import get_config
from repro.configs.base import FLConfig
from repro.configs.tiny import TINY
from repro.core import (Client, DenseSpace, FederatedZO, LoRASpace,
                        magnitude_mask, pretrain_gradient_vec, random_mask,
                        sensitivity_mask)
from repro.data.corpus import pretrain_batches
from repro.data.partition import (dirichlet_partition, iid_partition,
                                  single_label_partition, subset)
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.models import Model
from repro.models.transformer import DEFAULT_CTX


def build_space(method, loss_fn, params, pre, density, seed):
    if method == "meerkat":
        return sensitivity_mask(loss_fn, params, pre, density)
    if method == "magnitude":
        return magnitude_mask(params, density)
    if method == "random":
        return random_mask(params, density, seed=seed, balanced=False)
    if method == "full":
        return DenseSpace(params)
    if method == "lora":
        return LoRASpace(params)
    raise ValueError(method)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny",
                    help="tiny or any registered arch (reduced variant used)")
    ap.add_argument("--method", default="meerkat",
                    choices=["meerkat", "magnitude", "random", "full", "lora"])
    ap.add_argument("--partition", default="dirichlet",
                    choices=["iid", "dirichlet", "single_label", "mixed"])
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--T", type=int, default=10)
    ap.add_argument("--lr", type=float, default=5e-2)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--density", type=float, default=1e-2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--zo-backend", default="auto",
                    choices=["auto", "pallas", "ref"],
                    help="ZO perturb/update route (core/dispatch.py)")
    ap.add_argument("--attn-backend", default="auto",
                    choices=["auto", "pallas", "online", "dense"],
                    help="forward-attention route for the ZO loss forwards")
    ap.add_argument("--mesh", default=None,
                    help="run rounds sharded on a device mesh: DxM / PxDxM "
                         "host devices (e.g. 2x2), or single|multi for the "
                         "production 16x16 / 2x16x16 topologies")
    ap.add_argument("--mesh-rule", default="fsdp",
                    choices=["fsdp", "tp", "replicate"],
                    help="parameter sharding rule under --mesh "
                         "(sharding/fl.py; fsdp is bit-exact vs single "
                         "device, tp is allclose-level)")
    ap.add_argument("--vp", action="store_true",
                    help="MEERKAT-VP: calibrate GradIP + early-stop")
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--out", default=None, help="write history json here")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="write server snapshots here (ckpt_latest every "
                         "--checkpoint-every rounds, ckpt_final at the end)")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="rounds between snapshots under --checkpoint-dir")
    ap.add_argument("--resume", action="store_true",
                    help="restore ckpt_latest from --checkpoint-dir and "
                         "continue to --rounds (bit-exact vs uninterrupted)")
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="per-(round, client) offline probability "
                         "(repro.fault.FaultPlan)")
    ap.add_argument("--late-rate", type=float, default=0.0,
                    help="per-(round, client) straggler probability; "
                         "uploads land 1..--max-staleness rounds late")
    ap.add_argument("--max-staleness", type=int, default=2,
                    help="straggler staleness bound in rounds")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed of the deterministic fault schedule")
    ap.add_argument("--kill-at-round", type=int, default=None,
                    help="SIGKILL this process mid-round r (fault-injection "
                         "harness; see tools/kill_recover.py)")
    ap.add_argument("--sample-frac", type=float, default=1.0,
                    help="per-round participation fraction; < 1 enables the "
                         "seeded ClientSampler (cohort size "
                         "max(1, round(frac*K)); DESIGN.md §12)")
    ap.add_argument("--sample-weighted", action="store_true",
                    help="weight cohort draws by client dataset size "
                         "(uniform otherwise)")
    ap.add_argument("--quantize", default="none",
                    choices=["none", "int8", "int4", "int8-nearest",
                             "int4-nearest"],
                    help="uplink codec for the ZO scalars "
                         "(core/quantize.py exact-replay quantizer)")
    a = ap.parse_args()

    cfg = TINY if a.arch == "tiny" else get_config(a.arch).reduced()
    if a.method == "lora" and cfg.lora_rank == 0:
        cfg = cfg.replace(lora_rank=4)
    spec = TaskSpec(vocab=min(cfg.vocab, 512), seq_len=16)
    ctx = dataclasses.replace(DEFAULT_CTX, attn_backend=a.attn_backend)
    plan = None
    if a.mesh:
        from repro.sharding.fl import make_fl_plan
        plan = make_fl_plan(spec=a.mesh, rule=a.mesh_rule)
        print(f"mesh: {a.mesh} ({plan.mesh_cfg.n_devices} devices, "
              f"rule={a.mesh_rule}, client axis over {plan.batch_axes})")
    model = Model(cfg, ctx=ctx)
    print(f"arch={cfg.name} params={model.n_params:,} method={a.method}")

    params = model.init(jax.random.key(a.seed))
    loss, per_example, evaluate = make_task_fns(model, spec)
    lm_loss_fn = lambda p, b: model.loss(p, b)
    pre = pretrain_batches(spec, n_batches=8, batch_size=32, seed=a.seed + 3)

    t0 = time.time()
    space = build_space(a.method, lm_loss_fn, params, pre, a.density, a.seed)
    print(f"space: n={space.n:,} coords ({time.time() - t0:.1f}s)")

    train = sample_dataset(spec, 2048, seed=a.seed + 1)
    ev = sample_dataset(spec, 512, seed=a.seed + 2)
    eval_batch = {k: np.asarray(v) for k, v in ev.items()}
    labels = train["label"]
    if a.partition == "iid":
        parts = iid_partition(len(labels), a.clients, seed=a.seed)
    elif a.partition == "dirichlet":
        parts = dirichlet_partition(labels, a.clients, a.alpha, seed=a.seed)
    elif a.partition == "single_label":
        parts = single_label_partition(labels, a.clients, seed=a.seed)
    else:  # mixed: 3/4 mildly heterogeneous + 1/4 single-label extremes
        nb = max(1, a.clients * 3 // 4)
        parts = (dirichlet_partition(labels, nb, 5.0, seed=a.seed)
                 + single_label_partition(labels, a.clients - nb,
                                          seed=a.seed + 1))
    clients = [Client(k, subset(train, p), a.batch)
               for k, p in enumerate(parts)]

    fl = FLConfig(n_clients=a.clients, rounds=a.rounds, local_steps=a.T,
                  lr=a.lr, eps=a.eps, density=a.density, seed=a.seed,
                  zo_backend=a.zo_backend,
                  batch_size=a.batch, vp_calibration_steps=100,
                  vp_init_steps=20, vp_later_steps=20, vp_rho_later=2.0,
                  vp_sigma=0.25, vp_sigma_relative=True,
                  sample_frac=a.sample_frac,
                  sample_weighted=a.sample_weighted, quantize=a.quantize)
    server = FederatedZO(loss, params, space, fl, clients, eval_fn=evaluate,
                         plan=plan)
    if server.sampler is not None or server.codec.spec != "none":
        m = "full" if server.sampler is None else server.sampler.m
        print(f"fleet: cohort {m}/{a.clients} per round"
              + (" (weighted)" if a.sample_weighted else "")
              + f", uplink codec {server.codec.spec}")

    fault_plan = None
    if a.drop_rate or a.late_rate or a.kill_at_round is not None:
        from repro.fault import FaultPlan
        kills = (a.kill_at_round,) if a.kill_at_round is not None else ()
        fault_plan = FaultPlan(a.clients, a.rounds, drop_rate=a.drop_rate,
                               late_rate=a.late_rate,
                               max_staleness=a.max_staleness,
                               seed=a.fault_seed, kill_rounds=kills)
        print("faults:", fault_plan.summary())

    resumed = False
    if a.resume:
        if not a.checkpoint_dir:
            ap.error("--resume requires --checkpoint-dir")
        latest = os.path.join(a.checkpoint_dir, LATEST_NAME)
        server.load_checkpoint(latest)
        resumed = True
        print(f"resumed from {latest} at round {server.round}")

    if a.vp and not resumed:
        # (resume restores the calibrated VPCS flags and the consumed data
        # pointers; recalibrating would reset both and break bit-exactness)
        gp = pretrain_gradient_vec(lm_loss_fn, params, space, pre)
        results, flagged, _ = server.calibrate_vp(gp)
        print(f"VPCS flagged clients {flagged} "
              f"(rho_later={[round(r.rho_later, 2) for r in results]})")

    m0 = evaluate(server.params, eval_batch)
    print(f"round {server.round}: acc={float(m0['acc']):.4f} "
          f"loss={float(m0['loss']):.4f}")
    server.run(max(0, a.rounds - server.round), eval_every=a.eval_every,
               eval_batch=eval_batch, verbose=True, fault_plan=fault_plan,
               checkpoint_dir=a.checkpoint_dir,
               checkpoint_every=a.checkpoint_every)
    if a.checkpoint_dir:
        final = server.save_checkpoint(os.path.join(a.checkpoint_dir,
                                                    FINAL_NAME))
        print("wrote", final)
    m = evaluate(server.params, eval_batch)
    print(f"final: acc={float(m['acc']):.4f} loss={float(m['loss']):.4f} "
          f"({time.time() - t0:.0f}s total)  comm: up={server.comm.up_bytes}B "
          f"down={server.comm.down_bytes}B")
    if a.out:
        os.makedirs(os.path.dirname(a.out) or ".", exist_ok=True)
        with open(a.out, "w") as f:
            json.dump({"history": server.history,
                       "final": {k: float(v) for k, v in m.items()},
                       "args": vars(a)}, f, indent=1)
        print("wrote", a.out)


if __name__ == "__main__":
    main()
