"""Serving driver: continuous-batching generation with any registered arch.

Demonstrates the inference path the decode_32k / long_500k dry-run shapes
lower: per-request bucketed prefill into fixed-capacity decode slots, then
compiled one-token decode steps over all active slots, with mid-decode
admission and per-slot early exit (serving/engine.py).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b --requests 6
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.tiny import TINY
from repro.models import Model
from repro.models.transformer import DEFAULT_CTX
from repro.serving.engine import ContinuousBatchingEngine, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "naive"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "ref"],
                    help="decode-attention route (continuous engine)")
    ap.add_argument("--attn-backend", default="auto",
                    choices=["auto", "pallas", "online", "dense"],
                    help="prefill forward-attention route")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="decode slots (continuous) / batch size (naive)")
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()

    cfg = TINY if a.arch == "tiny" else get_config(a.arch).reduced()
    ctx = dataclasses.replace(DEFAULT_CTX, attn_backend=a.attn_backend)
    model = Model(cfg, ctx=ctx)
    params = model.init(jax.random.key(a.seed))
    print(f"arch={cfg.name} params={model.n_params:,} engine={a.engine}")

    rng = np.random.default_rng(a.seed)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(4, 24)))
               for _ in range(a.requests)]
    t0 = time.time()
    if a.engine == "continuous":
        engine = ContinuousBatchingEngine(
            model, params, max_slots=a.max_batch, S_max=a.s_max, bucket=16,
            decode_backend=a.backend, attn_backend=a.attn_backend)
        for p in prompts:
            engine.submit(p, max_new_tokens=a.max_new)
        outs = engine.run()
        stats = engine.stats
    else:
        engine = ServeEngine(model, params, max_batch=a.max_batch, bucket=16)
        for p in prompts:
            engine.submit(p, max_new_tokens=a.max_new)
        outs = engine.flush()
        stats = {}
    dt = time.time() - t0
    for i, o in enumerate(outs):
        print(f"req {i}: generated {len(o)} tokens: {o.tolist()}")
    n_tok = sum(len(o) for o in outs)
    extra = (f" ttft={stats['ttft_mean_s']:.2f}s "
             f"compiles={stats['compile_misses']}" if stats else "")
    print(f"{n_tok} tokens in {dt:.1f}s ({n_tok / dt:.1f} tok/s,"
          f" {a.engine} batching with cache{extra})")


if __name__ == "__main__":
    main()
