"""Serving driver: batched generation with any registered arch (reduced).

Demonstrates the inference path the decode_32k / long_500k dry-run shapes
lower: prefill + KV/SSM-state cache + one-token decode steps, through the
batched ServeEngine.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-27b --requests 6
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.tiny import TINY
from repro.models import Model
from repro.serving.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()

    cfg = TINY if a.arch == "tiny" else get_config(a.arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(a.seed))
    print(f"arch={cfg.name} params={model.n_params:,}")

    rng = np.random.default_rng(a.seed)
    engine = ServeEngine(model, params, max_batch=a.max_batch, bucket=16)
    t0 = time.time()
    for i in range(a.requests):
        prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(4, 24)))
        engine.submit(prompt, max_new_tokens=a.max_new)
    outs = engine.flush()
    dt = time.time() - t0
    for i, o in enumerate(outs):
        print(f"req {i}: generated {len(o)} tokens: {o.tolist()}")
    n_tok = sum(len(o) for o in outs)
    print(f"{n_tok} tokens in {dt:.1f}s ({n_tok / dt:.1f} tok/s, "
          f"batched prefill+decode with cache)")


if __name__ == "__main__":
    main()
