import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on the production mesh and extract the roofline raw data.

For each combo we do up to three compiles:

1. ``full``  — full-depth model with lax.scan over layer periods: proves the
   sharding lowers/compiles, and yields ``memory_analysis()`` (per-device
   argument/temp/output bytes — scan reuses one period's buffers, as on TPU).
2. ``fit1`` / ``fit2`` — depth-1 and depth-2 variants with every scan fully
   unrolled: XLA's HloCostAnalysis counts while-loop bodies once, so FLOPs /
   bytes / collective-bytes from a scanned module undercount by the trip
   count.  From the two unrolled points we fit ``f(n) = outside + n*body``
   and extrapolate exactly to the full depth.  (Methodology validated in
   EXPERIMENTS.md §Dry-run; the sLSTM time recurrence stays a scan — its
   per-step FLOPs are negligible and documented.)

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out runs/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config, get_shape
from repro.configs.base import InputShape, MeshConfig, ModelConfig
from repro.core.fl_step import make_fl_train_step
from repro.core.masks import abstract_mask
from repro.core.spaces import MaskedSpace
from repro.launch.hlo_tools import (COLLECTIVE_OPS, collective_bytes,
                                    cost_analysis)
from repro.launch.mesh import make_mesh_from_config, mesh_config
from repro.models import abstract_cache, abstract_params, decode_step, prefill
from repro.models.init import active_param_count, param_count
from repro.models.model import input_specs
from repro.models.transformer import ShardCtx, lm_loss
from repro.sharding.rules import (batch_specs, cache_specs, fsdp_only_specs,
                                  param_specs)

P = jax.sharding.PartitionSpec

DTYPE = jnp.bfloat16
FL_EPS = 1e-3
FL_LR = 1e-4


def _shallow_cfg(cfg: ModelConfig, n: int) -> ModelConfig:
    kw = dict(n_layers=cfg.period * n)
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=n)
    return cfg.replace(**kw)


def _largest_block(S: int, target: int) -> int:
    """Largest divisor of S that is <= target (for q-block chunking)."""
    b = min(target, S)
    while S % b:
        b -= 1
    return b


def make_ctx(cfg: ModelConfig, shape: InputShape, mesh, mc: MeshConfig,
             unroll_all: bool = False, n_periods: Optional[int] = None):
    dp = mc.data * mc.pods
    seq_shard = shape.global_batch % dp != 0
    B_loc = max(1, shape.global_batch // dp)
    S = shape.seq_len + (cfg.n_patches if cfg.frontend == "vision_stub" else 0)
    q_block = 0
    if shape.kind != "decode" and S > 2048:
        # keep per-device f32 scores [B_loc, H, q_block, S] under ~1.5 GB
        budget = int(1.5e9)
        h_loc = max(1, cfg.n_heads // mc.model)
        target = max(128, budget // max(1, B_loc * h_loc * S * 4))
        q_block = _largest_block(S, min(target, 2048))
    mlstm_block = 0
    if cfg.xlstm is not None and shape.kind != "decode" and S > 2048:
        mlstm_block = _largest_block(S, 512)
    return ShardCtx(
        mesh=mesh, batch_axes=mc.batch_axes, model_axis="model",
        use_sharded_moe=cfg.moe is not None and shape.kind != "decode"
        and not seq_shard,
        attn_q_block=q_block, mamba_chunk=64, mlstm_block=mlstm_block,
        scan_unroll=(n_periods or cfg.n_periods) if unroll_all else 1,
        unroll_chunks=unroll_all, seq_shard=seq_shard,
        # dry-run models the Pallas selective-scan kernel's HBM footprint
        # (read dt/B/C/x once, write y once) — §Perf pair 3
        mamba_mode="stub" if shape.kind != "decode" else "scan")


def build_lowerable(cfg: ModelConfig, shape: InputShape, mesh,
                    mc: MeshConfig, step_kind: str, unroll_all: bool = False):
    """Returns (jitted_fn, abstract_args) ready for .lower()."""
    ctx = make_ctx(cfg, shape, mesh, mc, unroll_all=unroll_all)
    ap = abstract_params(cfg, dtype=DTYPE)
    pspecs = param_specs(cfg, ap, mc,
                         train=step_kind in ("zo_fl", "first_order"))
    sh = lambda spec: jax.sharding.NamedSharding(mesh, spec)
    bspecs = batch_specs(cfg, shape, mc)
    binputs = input_specs(cfg, shape, dtype=DTYPE)

    if step_kind == "zo_dp":
        # Beyond-paper ZO sharding (§Perf pair 2): no tensor parallelism —
        # all mesh axes act as the FL-client/data axis, weights are pure
        # FSDP and get gathered once per layer period inside the scan.
        all_axes = tuple(mc.axis_names)
        pspecs = fsdp_only_specs(cfg, ap, mc)
        ctx = dataclasses.replace(
            ctx, batch_axes=all_axes, use_sharded_moe=False,
            online_attn=True, attn_q_block=512)
        bspecs = {k: P(*((all_axes,) + (None,) * (len(v) - 1)))
                  for k, v in bspecs.items()}
        step_kind = "zo_fl"
    pshard = jax.tree.map(sh, pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    bshard = {k: sh(v) for k, v in bspecs.items()}

    if step_kind == "zo_fl":
        idx_tree, eff_density = abstract_mask(ap, density=1e-3)
        ishard = jax.tree.map(lambda l: sh(P(None)), idx_tree,
                              is_leaf=lambda x: isinstance(
                                  x, jax.ShapeDtypeStruct))
        dp = 1
        for a in ctx.batch_axes:
            dp *= int(mesh.shape[a])
        n_clients = dp if shape.global_batch % dp == 0 else 1

        def constrain_params(p):
            return jax.tree.map(
                lambda a, s: jax.lax.with_sharding_constraint(a, sh(s)),
                p, pspecs, is_leaf=lambda x: isinstance(x, P))

        def fn(params, idx_tree, seed, batch):
            space = MaskedSpace(idx_tree)
            step = make_fl_train_step(
                lambda p, b: lm_loss(p, b, cfg, ctx, per_example=True),
                space, eps=FL_EPS, lr=FL_LR, n_clients=n_clients,
                constrain_params=constrain_params)
            return step(params, jax.random.key(seed), batch)

        jf = jax.jit(fn, in_shardings=(pshard, ishard, sh(P()), bshard),
                     out_shardings=(pshard, sh(P(None)), None),
                     donate_argnums=(0,))
        args = (ap, idx_tree, jax.ShapeDtypeStruct((), jnp.uint32), binputs)
        return jf, args

    if step_kind == "first_order":
        def fn(params, batch):
            g = jax.grad(lambda p: lm_loss(p, batch, cfg, ctx))(params)
            return jax.tree.map(lambda p, gg: p - FL_LR * gg.astype(p.dtype),
                                params, g)

        jf = jax.jit(fn, in_shardings=(pshard, bshard),
                     out_shardings=pshard, donate_argnums=(0,))
        return jf, (ap, binputs)

    if step_kind == "prefill":
        def fn(params, batch):
            return prefill(params, batch, cfg, ctx)

        jf = jax.jit(fn, in_shardings=(pshard, bshard))
        return jf, (ap, binputs)

    if step_kind == "decode":
        S_tot = shape.seq_len + (cfg.n_patches
                                 if cfg.frontend == "vision_stub" else 0)
        ac = abstract_cache(cfg, shape.global_batch, S_tot, dtype=DTYPE)
        cspecs = cache_specs(cfg, ac, shape, mc)
        cshard = jax.tree.map(sh, cspecs, is_leaf=lambda x: isinstance(x, P))

        def fn(params, token, cache):
            return decode_step(params, token, cache, cfg, ctx)

        jf = jax.jit(fn, in_shardings=(pshard, bshard["token"], cshard),
                     out_shardings=(None, cshard), donate_argnums=(2,))
        return jf, (ap, binputs["token"], ac)

    raise ValueError(step_kind)


STEP_FOR_SHAPE = {"train": "zo_fl", "prefill": "prefill", "decode": "decode"}


def applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


def run_combo(arch: str, shape_name: str, multi_pod: bool,
              step_kind: Optional[str] = None, fit: bool = True,
              verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mc = mesh_config(multi_pod=multi_pod)
    mesh = make_mesh_from_config(mc)
    step_kind = step_kind or STEP_FOR_SHAPE[shape.kind]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "step": step_kind, "ok": False,
           "n_params": param_count(cfg),
           "n_active_params": active_param_count(cfg),
           "n_devices": mc.n_devices}
    if not applicable(cfg, shape):
        rec["skipped"] = "long_500k requires a sub-quadratic mixer (DESIGN.md)"
        return rec
    try:
        # ---- full-depth compile: sharding proof + memory analysis ----------
        t0 = time.time()
        jf, args = build_lowerable(cfg, shape, mesh, mc, step_kind)
        lowered = jf.lower(*args)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_est_bytes": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
        }
        ca = cost_analysis(compiled)
        rec["cost_full_scan"] = {"flops": float(ca.get("flops", 0.0)),
                                 "bytes": float(ca.get("bytes accessed", 0.0))}
        rec["collectives_full_scan"] = collective_bytes(compiled.as_text())

        # ---- unrolled depth-1/2 compiles -> exact extrapolation -------------
        if fit:
            pts = {}
            for n in (1, 2):
                cfg_n = _shallow_cfg(cfg, n)
                jfn, argsn = build_lowerable(cfg_n, shape, mesh, mc,
                                             step_kind, unroll_all=True)
                cn = jfn.lower(*argsn).compile()
                can = cost_analysis(cn)
                pts[n] = {
                    "flops": float(can.get("flops", 0.0)),
                    "bytes": float(can.get("bytes accessed", 0.0)),
                    "coll": collective_bytes(cn.as_text()),
                }
            rec["fit_points"] = pts
            nper = cfg.n_periods
            def extrap(k):
                return pts[1][k] + (pts[2][k] - pts[1][k]) * (nper - 1)
            rec["cost"] = {"flops": extrap("flops"), "bytes": extrap("bytes")}
            rec["collectives"] = {
                op: pts[1]["coll"][op]
                + (pts[2]["coll"][op] - pts[1]["coll"][op]) * (nper - 1)
                for op in COLLECTIVE_OPS}
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(rec["error"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--step", default=None,
                    help="override step kind (zo_fl|first_order|prefill|decode)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fit", action="store_true",
                    help="skip the depth-1/2 cost-fit compiles")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = sorted(ASSIGNED) if (args.all or not args.arch) else [args.arch]
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if (args.all or not args.shape) else [args.shape])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}"
                if args.step:
                    tag += f"_{args.step}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {tag} (cached)")
                    continue
                print(f"[run ] {tag} ...", flush=True)
                t0 = time.time()
                # fit compiles only needed on the single-pod roofline mesh
                rec = run_combo(arch, shape, mp, step_kind=args.step,
                                fit=(not args.no_fit) and not mp)
                rec["wall_s"] = round(time.time() - t0, 1)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = "ok" if rec["ok"] else (
                    "SKIP" if "skipped" in rec else "FAIL")
                print(f"[{status:4s}] {tag} wall={rec['wall_s']}s", flush=True)


if __name__ == "__main__":
    main()
