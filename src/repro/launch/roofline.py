"""Roofline analysis (deliverable g): derive the three roofline terms from
the dry-run's compiled artifacts and report per (arch x shape) on the
single-pod production mesh.

    compute term    = HLO_FLOPs            / peak_FLOP/s      (per chip)
    memory  term    = HLO_bytes            / HBM_bw           (per chip)
    collective term = sum(op_bytes x ring_factor) / ICI_bw    (per chip)

The dry-run stores *per-device* cost numbers (the partitioned executable's
HLO), extrapolated exactly over the layer scan (dryrun.py fit method), so
no division by chip count here.  MODEL_FLOPS uses the analytic active-param
count: ZO-FL = 2 forwards = 4*N_active*tokens, prefill = 2*N*tokens,
decode = 2*N*batch (one token each).

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--dir runs/dryrun]
      [--md runs/roofline.md] [--mesh single]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List, Optional

from repro.configs.base import HW
from repro.launch.hlo_tools import COLLECTIVE_FACTOR

SHAPE_TOKENS = {  # (global_batch, seq_len)
    "train_4k": (256, 4096),
    "prefill_32k": (32, 32768),
    "decode_32k": (128, 1),
    "long_500k": (1, 1),
}

# Peak FLOP/s per measurement platform, for *measured*-MFU accounting
# (benchmarks/attn_bench.py divides achieved FLOP/s by this).  Keys match
# ``kernels.autotune.platform_key()``: the accelerator device kind, or
# "interpret" off-TPU (Pallas interpreter; the nominal host-f32 peak makes
# interpret-mode MFU comparable across rows, not meaningful in absolute
# terms — DESIGN.md §6).  Unknown platforms raise via
# :func:`host_peak_flops` rather than silently producing null MFU.
HOST_PEAK_FLOPS = {
    "tpu_v5_lite": HW["peak_flops_bf16"],   # v5e, per chip
    "tpu_v4": 275e12,
    "cpu": 1e11,        # nominal single-socket f32 host peak
    "interpret": 1e11,  # same host peak; kernels run interpreted
}


def host_peak_flops(platform: Optional[str] = None) -> float:
    """Peak FLOP/s for the measurement platform (default: this host's
    ``kernels.autotune.platform_key()``).  Raises KeyError for platforms
    missing from ``HOST_PEAK_FLOPS`` — MFU must never silently be null."""
    if platform is None:
        from repro.kernels.autotune import platform_key
        platform = platform_key()
    if platform not in HOST_PEAK_FLOPS:
        raise KeyError(
            f"no peak-FLOP/s entry for platform {platform!r}: add it to "
            f"launch/roofline.py HOST_PEAK_FLOPS "
            f"(have {sorted(HOST_PEAK_FLOPS)})")
    return HOST_PEAK_FLOPS[platform]


def attention_flops(cfg, B: int, S: int, causal: bool = True) -> float:
    """Matmul FLOPs of the attention score + value contractions for one
    full-model forward: 4 * pairs * head_dim per (batch, head), with
    ``pairs`` the live (query, key) count — S(S+1)/2 causal, banded to the
    sliding window on 'local_attn' layers, per the layer pattern."""
    hd = cfg.resolved_head_dim
    H = cfg.n_heads

    def pairs(window: int) -> float:
        if not causal:
            return float(S) * S
        full = S * (S + 1) / 2
        if window and window < S:
            # banded: query t sees min(t+1, w) keys; the sum telescopes to
            # full minus the (S-w)-row tail triangle
            return full - (S - window) * (S - window + 1) / 2
        return full

    total = 0.0
    for mixer, _ in cfg.layer_pattern:
        if mixer == "attn":
            total += pairs(0)
        elif mixer == "local_attn":
            total += pairs(cfg.sliding_window)
    return 4.0 * B * H * hd * total * cfg.n_periods


def forward_model_flops(cfg, B: int, S: int) -> float:
    """Analytic FLOPs for one forward: 2 * N_active per token (matmul
    MACs x2, MoE-aware) plus the quadratic attention term."""
    from repro.models.init import active_param_count
    return 2.0 * active_param_count(cfg) * B * S + attention_flops(cfg, B, S)


def step_model_flops(cfg, B: int, S: int, step: str) -> float:
    """Forward-equivalents per benchmark step: prefill = 1 forward,
    zo_step = 2 (the MEERKAT dual forward, Eq. 1, n_dirs=1), first_order =
    3 (forward + ~2x backward).  Unknown steps raise."""
    fwd = forward_model_flops(cfg, B, S)
    mult = {"prefill": 1.0, "forward": 1.0, "zo_step": 2.0,
            "first_order": 3.0}
    if step not in mult:
        raise KeyError(f"no FLOPs model for step {step!r} "
                       f"(have {sorted(mult)})")
    return mult[step] * fwd


def model_flops_per_device(rec: dict) -> float:
    """Analytic 'useful' FLOPs per device for the lowered step."""
    B, S = SHAPE_TOKENS[rec["shape"]]
    n_act = rec["n_active_params"]
    tokens = B * S
    if rec["step"] in ("zo_fl", "zo_dp"):
        per_tok = 4 * n_act        # two forwards, no backward
    elif rec["step"] == "first_order":
        per_tok = 6 * n_act
    else:                          # prefill / decode: one forward
        per_tok = 2 * n_act
    return per_tok * tokens / rec["n_devices"]


def analyze(rec: dict) -> Optional[dict]:
    if not rec.get("ok"):
        return None
    cost = rec.get("cost") or rec.get("cost_full_scan")
    coll = rec.get("collectives") or rec.get("collectives_full_scan") or {}
    # depth-1/2 extrapolation can go slightly negative when XLA fuses a
    # collective away at depth 2 — clamp each term to >= 0
    t_comp = max(0.0, cost["flops"]) / HW["peak_flops_bf16"]
    t_mem = max(0.0, cost["bytes"]) / HW["hbm_bw"]
    coll_bytes = sum(max(0.0, v) * COLLECTIVE_FACTOR[k]
                     for k, v in coll.items())
    t_coll = coll_bytes / HW["ici_bw"]
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    useful = mf / max(cost["flops"], 1.0)
    t_bound = max(terms.values())
    # MFU if the dominant term were the wall clock
    mfu = mf / HW["peak_flops_bf16"] / max(t_bound, 1e-30)
    return dict(arch=rec["arch"], shape=rec["shape"], step=rec["step"],
                mesh=rec["mesh"], compute_s=t_comp, memory_s=t_mem,
                collective_s=t_coll, dominant=dominant,
                collective_bytes=coll_bytes,
                model_flops_per_dev=mf, hlo_flops_per_dev=cost["flops"],
                useful_flop_ratio=useful, bound_mfu=mfu,
                peak_bytes_per_dev=rec["memory"]["peak_est_bytes"],
                note=suggest(dominant, rec))


def suggest(dominant: str, rec: dict) -> str:
    step = rec["step"]
    if dominant == "collective":
        return ("shrink cross-shard traffic: fewer all-gathers of sharded "
                "weights (batch the ZO scalar psum, keep scatters sharded)")
    if dominant == "memory":
        if step == "decode":
            return ("decode is KV/state-bandwidth bound: shrink cache dtype "
                    "(int8 KV), fuse the per-token weight read (multi-token "
                    "speculative or batched decode amortizes it)")
        return ("re-materialize less / fuse elementwise chains so each "
                "weight+activation byte is read once per layer")
    if step == "zo_fl":
        return ("compute-bound: ZO forward pair is matmul-dominated — raise "
                "MXU utilization (bigger per-device batch, bf16 everywhere)")
    return "compute-bound: increase arithmetic intensity per HBM byte"


def collect(dirname: str, mesh: str = "single") -> List[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        rec = json.load(open(path))
        if rec.get("mesh") != mesh:
            continue
        r = analyze(rec)
        if r:
            rows.append(r)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def to_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | step | compute | memory | collective | "
           "dominant | useful/HLO | bound MFU |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        body += (f"| {r['arch']} | {r['shape']} | {r['step']} | "
                 f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
                 f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
                 f"{r['useful_flop_ratio']:.2f} | {r['bound_mfu'] * 100:.1f}% |\n")
    return hdr + body


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--md", default=None)
    ap.add_argument("--json", default=None)
    a = ap.parse_args()
    rows = collect(a.dir, a.mesh)
    md = to_markdown(rows)
    print(md)
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"{len(rows)} rows; dominant-term counts: {doms}")
    if a.md:
        with open(a.md, "w") as f:
            f.write(md)
    if a.json:
        with open(a.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
