"""HLO inspection helpers for the perf hillclimb (§Perf methodology).

The dry-run profile is ``lowered/compiled.as_text()`` + ``cost_analysis()``;
this module extracts the *largest* collective / copy ops with shapes so a
hypothesis can name the exact tensor whose movement it claims to remove.

Usage:
  PYTHONPATH=src python -m repro.launch.hlo_tools --arch qwen2-7b \
      --shape decode_32k [--top 15] [--depth 1]
"""
from __future__ import annotations

import argparse
import re
from collections import defaultdict
from typing import List, Tuple

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
# per-device traffic multiplier relative to the op's output bytes (ring algs)
COLLECTIVE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                     "reduce-scatter": 1.0, "all-to-all": 1.0,
                     "collective-permute": 1.0}
OPS = COLLECTIVE_OPS + ("copy", "dynamic-update-slice", "dynamic-slice")


def shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in (per-device) HLO text.

    Returns ``{op: bytes}`` over :data:`COLLECTIVE_OPS` (async ``-start``
    forms counted once, ``-done`` forms skipped).  Used by the dry-run's
    roofline extraction and ``benchmarks/fl_scale_bench.py``; multiply by
    :data:`COLLECTIVE_FACTOR` for ring-algorithm wire traffic."""
    out = {op: 0.0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(\(?[\w\[\],{}\s/#*]*?)\s*(all-reduce|all-gather|"
                      r"reduce-scatter|all-to-all|collective-permute)"
                      r"(-start|-done)?\(", line)
        if not m or (m.group(3) == "-done"):
            continue
        out[m.group(2)] += shape_bytes(m.group(1))
    return out


def top_ops(hlo_text: str, ops=OPS, top: int = 20
            ) -> List[Tuple[int, str, str]]:
    """Largest ops by output bytes: (bytes, op, line-prefix)."""
    found = []
    pat = re.compile(r"=\s*(\(?[\w\[\],{}\s/#*]*?)\s*(" + "|".join(ops)
                     + r")(-start)?\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        b = shape_bytes(m.group(1))
        found.append((b, m.group(2), line.strip()[:180]))
    found.sort(key=lambda t: -t[0])
    return found[:top]


def op_totals(hlo_text: str, ops=OPS) -> dict:
    tot = defaultdict(float)
    pat = re.compile(r"=\s*(\(?[\w\[\],{}\s/#*]*?)\s*(" + "|".join(ops)
                     + r")(-start)?\(")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if m:
            tot[m.group(2)] += shape_bytes(m.group(1))
    return dict(tot)


def cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: older
    releases return a per-device list of dicts, newer ones a single dict
    (or None when the backend offers no analysis)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return ca or {}


def main():
    # import here so --xla_force_host_platform_device_count is set first
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    from repro.launch import dryrun as DR

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--step", default=None)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--depth", type=int, default=1,
                    help="periods to keep (unrolled); 0 = full scan")
    ap.add_argument("--multi", action="store_true")
    a = ap.parse_args()

    from repro.configs import get_config, get_shape
    from repro.launch.mesh import make_mesh_from_config, mesh_config

    cfg = get_config(a.arch)
    if a.depth:
        cfg = DR._shallow_cfg(cfg, a.depth)
    shape = get_shape(a.shape)
    mc = mesh_config(multi_pod=a.multi)
    mesh = make_mesh_from_config(mc)
    step = a.step or DR.STEP_FOR_SHAPE[shape.kind]
    jf, args = DR.build_lowerable(cfg, shape, mesh, mc, step,
                                  unroll_all=bool(a.depth))
    compiled = jf.lower(*args).compile()
    text = compiled.as_text()
    print(f"== {a.arch} x {a.shape} ({step}) depth={a.depth or 'full'} ==")
    print("op totals (per-device bytes):")
    for op, b in sorted(op_totals(text).items(), key=lambda kv: -kv[1]):
        print(f"  {op:22s} {b / 1e6:12.1f} MB")
    print(f"\ntop {a.top} ops:")
    for b, op, line in top_ops(text, top=a.top):
        print(f"  {b / 1e6:10.1f} MB  {line}")


if __name__ == "__main__":
    main()
