"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
does not touch jax device state.  The dry-run forces 512 host devices via
XLA_FLAGS *before* any jax import; real deployments get the same meshes from
actual TPU topologies.  ``parse_mesh_spec`` maps the CLI syntax shared by
``launch/train.py`` / ``benchmarks/fl_scale_bench.py`` /
``tools/fl_mesh_parity.py`` onto a :class:`MeshConfig`.
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def parse_mesh_spec(spec: str) -> MeshConfig:
    """CLI mesh spec -> :class:`MeshConfig`.

    Accepted forms:

    * ``"DxM"``     — single pod, D 'data' x M 'model' devices (``"2x2"``)
    * ``"PxDxM"``   — multi-pod, P 'pod' x D 'data' x M 'model' (``"2x16x16"``)
    * ``"single"``  — the production 16x16 single-pod mesh (256 chips)
    * ``"multi"``   — the production 2x16x16 multi-pod mesh (512 chips)

    ``"1x1"`` is a valid degenerate mesh (1 device) used by the parity
    tests as the smallest sharded configuration.
    """
    named = {"single": MeshConfig(data=16, model=16, pods=1),
             "multi": MeshConfig(data=16, model=16, pods=2)}
    if spec in named:
        return named[spec]
    parts = spec.split("x")
    try:
        dims = [int(p) for p in parts]
    except ValueError:
        raise ValueError(f"bad mesh spec {spec!r}: want DxM, PxDxM, "
                         f"or one of {sorted(named)}")
    if len(dims) == 2:
        return MeshConfig(data=dims[0], model=dims[1], pods=1)
    if len(dims) == 3:
        return MeshConfig(pods=dims[0], data=dims[1], model=dims[2])
    raise ValueError(f"bad mesh spec {spec!r}: want 2 or 3 'x'-separated dims")


def host_device_flag(n_devices: int) -> str:
    """The XLA flag forcing ``n_devices`` host (CPU) devices.

    Must be placed in ``XLA_FLAGS`` *before* the first jax import —
    callers that accept ``--mesh`` pre-parse argv for exactly this reason
    (see ``launch/train.py``)."""
    return f"--xla_force_host_platform_device_count={n_devices}"


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    return make_mesh_from_config(mesh_config(multi_pod=multi_pod))


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(data=16, model=16, pods=2 if multi_pod else 1)


def make_mesh_from_config(mc: MeshConfig):
    devices = jax.devices()[:mc.n_devices]
    if len(devices) < mc.n_devices:
        raise RuntimeError(
            f"need {mc.n_devices} devices for mesh {mc.shape}; have "
            f"{len(devices)}. Set XLA_FLAGS={host_device_flag(mc.n_devices)} "
            "before importing jax (see launch/dryrun.py).")
    return jax.make_mesh(mc.shape, mc.axis_names, devices=devices)
