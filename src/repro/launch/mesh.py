"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
does not touch jax device state.  The dry-run forces 512 host devices via
XLA_FLAGS *before* any jax import; real deployments get the same meshes from
actual TPU topologies.
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)}. "
            "Set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (see launch/dryrun.py).")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
                         devices=devices)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MeshConfig(data=16, model=16, pods=2 if multi_pod else 1)


def make_mesh_from_config(mc: MeshConfig):
    devices = jax.devices()[:mc.n_devices]
    if len(devices) < mc.n_devices:
        raise RuntimeError(f"need {mc.n_devices} devices, have {len(devices)}")
    return jax.make_mesh(mc.shape, mc.axis_names,
                         axis_types=(jax.sharding.AxisType.Auto,)
                         * len(mc.axis_names),
                         devices=devices)
