"""Deterministic fault schedules for federated rounds.

A :class:`FaultPlan` is a pure function of its constructor arguments —
the whole schedule (which client drops or straggles in which round, and
where the server is killed) is drawn once from a seeded generator at
construction.  That is what makes fault runs *replayable*: a resumed
process rebuilds the identical plan from the same flags, so rounds
re-executed after a crash see exactly the faults the dead process saw
(the bit-exact-resume invariant of DESIGN.md §11).

Per-round event kinds (consumed by ``FederatedZO.run_round``):

* **drop** — the client is offline for the round: it runs no local
  steps, uploads nothing, receives no downlink, and its data pointer
  does not advance.  The server aggregates over the survivors and logs
  an explicit GradIP gap for the client.
* **late** — a straggler: the client runs its local steps on the
  round's seeds/data as usual, but its scalar upload arrives
  ``delay`` rounds later (``1 <= delay <= max_staleness``).  Because the
  virtual path is reconstructed from ``(round seed keys, scalars)`` and
  the seed ladder is derivable from ``(fl.seed, round, T)``, the stale
  contribution is replayed *exactly* when it lands.
* **kill** — the server process dies mid-round (after client compute,
  before the aggregated update is applied): the crash/preemption case
  the checkpoint/resume path exists for.  The default killer is a real
  ``SIGKILL`` of the current process (no cleanup, no atexit) — tests
  monkeypatch :func:`kill_now`.
"""
from __future__ import annotations

import dataclasses
import os
import signal
from typing import Dict, FrozenSet, Mapping, Sequence

import numpy as np


def kill_now():  # pragma: no cover - exercised via tools/kill_recover.py
    """SIGKILL the current process: the unclean-death model. Module-level
    so harnesses/tests can monkeypatch it."""
    os.kill(os.getpid(), signal.SIGKILL)


@dataclasses.dataclass(frozen=True)
class RoundFaults:
    """The fault events of one round (``FaultPlan.round_faults``)."""
    drops: FrozenSet[int] = frozenset()
    late: Mapping[int, int] = dataclasses.field(default_factory=dict)
    kill: bool = False

    @property
    def empty(self) -> bool:
        return not (self.drops or self.late or self.kill)

    def restrict(self, cohort) -> "RoundFaults":
        """Project the round's client faults onto a sampled cohort
        (fleet-scale client sampling, DESIGN.md §12): drop/late events
        of clients outside the cohort are vacuous — the server never
        asked them to participate — so the effective faults are the
        plan's events intersected with the cohort.  ``kill`` is a
        server-side event and survives unchanged.  A fault plan drawn
        for the full fleet therefore composes with any participation
        fraction without redrawing the schedule."""
        if not (self.drops or self.late):
            return self
        cohort = frozenset(cohort)
        return RoundFaults(
            drops=self.drops & cohort,
            late={c: d for c, d in self.late.items() if c in cohort},
            kill=self.kill)


NO_FAULTS = RoundFaults()


class FaultPlan:
    """Seeded per-round schedule of client-drop / client-late /
    server-kill events.

    Each (round, client) cell draws one uniform: ``u < drop_rate`` is a
    drop, ``u < drop_rate + late_rate`` a straggler with delay drawn
    uniformly from ``[1, max_staleness]``.  Rounds at or beyond
    ``rounds`` are fault-free (so a resumed run that overshoots the
    planned horizon degrades to the clean protocol)."""

    def __init__(self, n_clients: int, rounds: int, *,
                 drop_rate: float = 0.0, late_rate: float = 0.0,
                 max_staleness: int = 2, seed: int = 0,
                 kill_rounds: Sequence[int] = ()):
        if not (0.0 <= drop_rate <= 1.0 and 0.0 <= late_rate <= 1.0
                and drop_rate + late_rate <= 1.0):
            raise ValueError(
                f"need drop_rate, late_rate >= 0 with sum <= 1; got "
                f"{drop_rate}, {late_rate}")
        if max_staleness < 1:
            raise ValueError(f"max_staleness must be >= 1, got "
                             f"{max_staleness}")
        if n_clients < 1 or rounds < 0:
            raise ValueError(f"need n_clients >= 1 and rounds >= 0; got "
                             f"{n_clients}, {rounds}")
        self.n_clients = int(n_clients)
        self.rounds = int(rounds)
        self.drop_rate = float(drop_rate)
        self.late_rate = float(late_rate)
        self.max_staleness = int(max_staleness)
        self.seed = int(seed)
        self.kill_rounds = frozenset(int(r) for r in kill_rounds)
        rng = np.random.default_rng(seed)
        u = rng.uniform(size=(self.rounds, self.n_clients))
        delays = rng.integers(1, self.max_staleness + 1,
                              size=(self.rounds, self.n_clients))
        self._schedule: Dict[int, RoundFaults] = {}
        for r in range(self.rounds):
            drops = frozenset(int(c) for c in np.nonzero(
                u[r] < self.drop_rate)[0])
            late = {int(c): int(delays[r, c])
                    for c in np.nonzero(
                        (u[r] >= self.drop_rate)
                        & (u[r] < self.drop_rate + self.late_rate))[0]}
            rf = RoundFaults(drops=drops, late=late,
                             kill=r in self.kill_rounds)
            if not rf.empty:
                self._schedule[r] = rf
        for r in self.kill_rounds - set(self._schedule):
            self._schedule[r] = RoundFaults(kill=True)

    def round_faults(self, r: int) -> RoundFaults:
        return self._schedule.get(int(r), NO_FAULTS)

    def kill_at(self, r: int) -> bool:
        return int(r) in self.kill_rounds

    def summary(self) -> dict:
        """Event counts over the horizon (for bench rows / logs)."""
        n_drop = sum(len(rf.drops) for rf in self._schedule.values())
        n_late = sum(len(rf.late) for rf in self._schedule.values())
        return dict(n_clients=self.n_clients, rounds=self.rounds,
                    drop_rate=self.drop_rate, late_rate=self.late_rate,
                    max_staleness=self.max_staleness, seed=self.seed,
                    n_drop_events=n_drop, n_late_events=n_late,
                    kill_rounds=sorted(self.kill_rounds))

    def __repr__(self):  # pragma: no cover - debugging aid
        s = self.summary()
        return (f"FaultPlan(K={s['n_clients']}, R={s['rounds']}, "
                f"drop={s['drop_rate']}, late={s['late_rate']}, "
                f"kills={s['kill_rounds']})")
