"""Fault tolerance for federated rounds: deterministic fault schedules
(``plan.py``) consumed by ``core/server.FederatedZO`` and the
checkpoint/resume path (``checkpoint/state.py``).  DESIGN.md §11."""
from repro.fault.plan import NO_FAULTS, FaultPlan, RoundFaults, kill_now

__all__ = ["FaultPlan", "RoundFaults", "NO_FAULTS", "kill_now"]
