from repro.train.first_order import fedavg_round, make_train_step
