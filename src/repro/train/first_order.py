"""First-order (backprop) training: used for (a) sensitivity-mask
calibration gradients, (b) the server-held GradIP pre-training gradient, and
(c) the FedAvg / data-parallel baseline the roofline compares against."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.layers import differentiable_attn
from repro.optim import make_optimizer


def make_train_step(loss_fn: Callable, optimizer: str = "sgd",
                    lr: float = 1e-3, **kw):
    """Returns (init_state, jittable step(params, opt_state, batch)).

    Grad traces run under :func:`differentiable_attn`: at blockwise S the
    "auto" backend resolves to the Pallas kernel's recompute-based VJP
    (``kernels/flash_attention.py``), whose O(S*dh) saved residuals bound
    the backward's attention memory — the analyzer's first_order
    memory-ceiling budget is sized against that recompute peak
    (``analysis/registry.py``)."""
    init, update = make_optimizer(optimizer, lr, **kw)

    def step(params, opt_state, batch):
        with differentiable_attn():
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        upd, opt_state = update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, upd)
        return params, opt_state, loss

    return init, jax.jit(step)


def fedavg_round(loss_fn: Callable, params, client_batches, lr: float,
                 local_steps: int = 1):
    """One FedAvg round (first-order baseline): each client runs SGD locally,
    the server averages the resulting models.

    client_batches: pytree with leading [K, T, b, ...]."""

    def client_run(p, batches):
        def one(pp, b):
            with differentiable_attn():
                g = jax.grad(loss_fn)(pp, b)
            pp = jax.tree.map(lambda w, gg: w - lr * gg.astype(w.dtype), pp, g)
            return pp, None

        pT, _ = jax.lax.scan(one, p, batches)
        return pT

    client_params = jax.vmap(client_run, in_axes=(None, 0))(params,
                                                            client_batches)
    return jax.tree.map(lambda c: jnp.mean(c, axis=0), client_params)
