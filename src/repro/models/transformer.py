"""Model application: training forward, prefill, and one-token decode, for
every architecture family, scanning over stacked layer periods.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL

P = jax.sharding.PartitionSpec


@dataclass(frozen=True)
class ShardCtx:
    """How model code should shard / chunk. ``mesh=None`` -> pure jnp."""
    mesh: Any = None
    batch_axes: Tuple[str, ...] = ()
    model_axis: str = "model"
    use_sharded_moe: bool = False
    attn_q_block: int = 0       # 0 -> full attention
    mamba_chunk: int = 64
    mlstm_block: int = 0
    scan_unroll: int = 1
    unroll_chunks: bool = False  # python-loop inner chunk loops (cost analysis)
    seq_shard: bool = False     # long-context decode: shard cache on seq
    remat: bool = False
    online_attn: bool = False   # flash-style online-softmax attention
    kv_block: int = 512         # KV block for online_attn
    mamba_mode: str = "scan"    # scan | kernel | stub (see ssm.mamba_forward)
    # decode-attention route (layers.resolve_decode_backend): "auto" runs the
    # Pallas flash-decode kernel when the layout supports it (interpret mode
    # off-TPU), "ref" the grouped jnp path (the only sharded-mesh choice)
    decode_backend: str = "auto"  # auto | pallas | ref
    # forward-attention route for training / prefill
    # (layers.resolve_attn_backend): "auto" consults the measured
    # kernels.autotune table, else heuristics — dense small-S, the
    # blockwise jnp online-softmax or the Pallas kernel at larger S; grad
    # traces prefer the kernel's recompute VJP (bounded backward memory)
    attn_backend: str = "auto"  # auto | pallas | online | dense

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    def constrain(self, x, spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    def attn_head_spec(self, B: int, S: int, H: int):
        """Spec for [B, S, H, hd] attention tensors (None -> no constraint)."""
        if self.mesh is None:
            return None
        # pure-DP ZO mode folds 'model' into batch_axes — no TP dims left
        tp_free = self.model_axis not in self.batch_axes
        tp = int(self.mesh.shape[self.model_axis]) if tp_free else 1
        dp = self.dp_size
        h = self.model_axis if (tp_free and H % tp == 0) else None
        if self.seq_shard:
            s = self.batch_axes if (S > 1 and S % dp == 0) else None
            return P(None, s, h, None)
        b = self.batch_axes if B % dp == 0 else None
        s = None
        if tp_free and h is None and S > 1 and S % tp == 0:
            s = self.model_axis
        return P(b, s, h, None)

    def act_spec(self, B: int):
        if self.mesh is None:
            return None
        if self.seq_shard or B % max(self.dp_size, 1):
            return P(None, self.batch_axes, None)  # shard sequence
        return P(self.batch_axes, None, None)


DEFAULT_CTX = ShardCtx()


def _sinusoid(S, D, offset=0):
    """[..., S, D] sinusoidal table; ``offset`` is a scalar or a per-row [B]
    vector (continuous-batching decode, where every slot sits at its own
    absolute position)."""
    off = jnp.asarray(offset, jnp.float32)
    pos = jnp.arange(S, dtype=jnp.float32) + off[..., None]  # [..., S]
    dim = jnp.arange(0, D, 2, dtype=jnp.float32)
    ang = pos[..., None] / jnp.power(10_000.0, dim / D)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe[..., :D]


def _maybe_posenc(x, cfg, offset=0):
    """Learned-free sinusoidal absolute positions for rope-less attention
    archs (whisper).  SSM/hybrid archs need none."""
    if cfg.rope_style == "none" and (cfg.encoder is not None
                                     or cfg.frontend == "audio_stub"):
        return x + _sinusoid(x.shape[1], x.shape[2], offset).astype(x.dtype)
    return x


# ------------------------------------------------------------- embedding --
def embed_input(params, batch, cfg: ModelConfig):
    """Assemble the input sequence [B, S_total, D] from tokens + frontend."""
    tok = params["embed"][batch["tokens"]]
    if cfg.embed_scale:
        tok = tok * jnp.asarray(cfg.d_model ** 0.5, tok.dtype)
    if cfg.frontend == "vision_stub":
        x = jnp.concatenate([batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
    else:
        x = tok
    return x


def unembed(x, params, cfg: ModelConfig):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return L.softcap(logits.astype(jnp.float32), cfg.final_softcap)


# ------------------------------------------------------------ layer apply --
def _mixer_fwd(x, lp, mixer, cfg, ctx, positions, enc_kv):
    h = L.apply_norm(x, lp["norm"], cfg.norm, cfg.norm_eps)
    if mixer in ("attn", "local_attn"):
        local = mixer == "local_attn"
        if ctx.attn_q_block and x.shape[1] % ctx.attn_q_block == 0 \
                and x.shape[1] > ctx.attn_q_block:
            y = L.self_attention_chunked(h, lp, cfg, positions, local=local,
                                         q_block=ctx.attn_q_block,
                                         unroll=ctx.unroll_chunks, ctx=ctx)
        else:
            y = L.self_attention(h, lp, cfg, positions, local=local, ctx=ctx)
    elif mixer == "mamba":
        y = SSM.mamba_forward(h, lp, cfg.ssm, chunk=ctx.mamba_chunk,
                              unroll=ctx.unroll_chunks, mode=ctx.mamba_mode)
    elif mixer == "mlstm":
        y = XL.mlstm_forward(h, lp, cfg.xlstm, block=ctx.mlstm_block,
                             unroll=ctx.unroll_chunks)
    elif mixer == "slstm":
        y = XL.slstm_forward(h, lp, cfg.xlstm)
    else:
        raise ValueError(mixer)
    if cfg.post_norms and "post_norm" in lp:
        y = L.apply_norm(y, lp["post_norm"], cfg.norm, cfg.norm_eps)
    x = x + y
    if enc_kv is not None and mixer in ("attn", "local_attn") and "cross" in lp:
        h = L.apply_norm(x, lp["cross"]["norm"], cfg.norm, cfg.norm_eps)
        x = x + L.cross_attention(h, enc_kv, lp["cross"], cfg, ctx)
    return x


def _ffn_fwd(x, lp, ffn, cfg, ctx, token_valid=None):
    """``token_valid``: decode-time [B] mask keeping inactive slots out of
    MoE capacity dispatch (see moe.moe_dense_ref)."""
    aux = jnp.zeros((), jnp.float32)
    if ffn == "none":
        return x, aux
    h = L.apply_norm(x, lp["norm2"], cfg.norm, cfg.norm_eps)
    if ffn == "dense":
        y = L.mlp(h, lp, cfg)
    else:
        y, aux = MOE.moe_ffn(h, lp, cfg.moe, cfg.act, ctx, valid=token_valid)
    if cfg.post_norms and "post_norm2" in lp:
        y = L.apply_norm(y, lp["post_norm2"], cfg.norm, cfg.norm_eps)
    return x + y, aux


def apply_layer(x, lp, mixer, ffn, cfg, ctx, positions, enc_kv=None):
    x = _mixer_fwd(x, lp, mixer, cfg, ctx, positions, enc_kv)
    return _ffn_fwd(x, lp, ffn, cfg, ctx)


# ------------------------------------------------------------ full stacks --
def stack_forward(x, stack, pattern, cfg, ctx, positions, enc_kv=None):
    spec = ctx.act_spec(x.shape[0])

    def body(carry, pp):
        xx, aux = carry
        for i, (mixer, ffn) in enumerate(pattern):
            fn = apply_layer
            if ctx.remat:
                fn = jax.checkpoint(apply_layer,
                                    static_argnums=(2, 3, 4, 5))
            xx, a = fn(xx, pp[f"p{i}"], mixer, ffn, cfg, ctx, positions, enc_kv)
            aux = aux + a
        if spec is not None:
            xx = ctx.constrain(xx, spec)
        return (xx, aux), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack,
                               unroll=ctx.scan_unroll)
    return x, aux


def encoder_forward(params, audio_embeds, cfg, ctx):
    enc = params["encoder"]
    x = audio_embeds + _sinusoid(audio_embeds.shape[1],
                                 cfg.d_model).astype(audio_embeds.dtype)

    def body(carry, pp):
        xx, _ = carry
        lp = pp["p0"]
        h = L.apply_norm(xx, lp["norm"], cfg.norm, cfg.norm_eps)
        xx = xx + L.bidir_attention(h, lp, cfg, ctx)
        xx, _ = _ffn_fwd(xx, lp, "dense", cfg, ctx)
        return (xx, jnp.zeros((), jnp.float32)), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             enc["stack"], unroll=ctx.scan_unroll)
    return L.apply_norm(x, enc["final_norm"], cfg.norm, cfg.norm_eps)


def forward(params, batch, cfg: ModelConfig, ctx: ShardCtx = DEFAULT_CTX):
    """Training forward: returns (logits [B, S_tokens, V], aux_loss)."""
    x = embed_input(params, batch, cfg)
    x = _maybe_posenc(x, cfg)
    spec = ctx.act_spec(x.shape[0])
    if spec is not None:
        x = ctx.constrain(x, spec)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    enc_kv = None
    if cfg.encoder is not None:
        enc_out = encoder_forward(params, batch["audio_embeds"].astype(x.dtype),
                                  cfg, ctx)
        enc_kv = enc_out  # per-layer K/V projected inside apply via lp: see below
    x, aux = _stack_with_cross(x, params["stack"], cfg, ctx, positions, enc_kv)
    x = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    logits = unembed(x, params, cfg)
    if cfg.frontend == "vision_stub":
        logits = logits[:, -batch["tokens"].shape[1]:]
    return logits, aux


def _stack_with_cross(x, stack, cfg, ctx, positions, enc_out):
    """Like stack_forward but projects per-layer cross K/V from enc_out."""
    if enc_out is None:
        return stack_forward(x, stack, cfg.layer_pattern, cfg, ctx, positions)
    spec = ctx.act_spec(x.shape[0])

    def body(carry, pp):
        xx, aux = carry
        for i, (mixer, ffn) in enumerate(cfg.layer_pattern):
            lp = pp[f"p{i}"]
            kv = L.encode_kv(enc_out, lp["cross"], cfg) if "cross" in lp else None
            xx = _mixer_fwd(xx, lp, mixer, cfg, ctx, positions, kv)
            xx, a = _ffn_fwd(xx, lp, ffn, cfg, ctx)
            aux = aux + a
        if spec is not None:
            xx = ctx.constrain(xx, spec)
        return (xx, aux), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack,
                               unroll=ctx.scan_unroll)
    return x, aux


# ------------------------------------------------------------------ loss --
def lm_loss(params, batch, cfg: ModelConfig, ctx: ShardCtx = DEFAULT_CTX,
            aux_weight: float = 0.01, per_example: bool = False):
    """Next-token cross-entropy (mean over non-pad positions)."""
    logits, aux = forward(params, batch, cfg, ctx)
    targets = batch["tokens"][:, 1:]
    lg = logits[:, :-1]
    # vocab-sharding-friendly CE: one-hot select fuses into the reduction,
    # so sharded-V logits never get all-gathered (unlike take_along_axis).
    lse = jax.nn.logsumexp(lg, axis=-1)
    vocab_iota = jnp.arange(lg.shape[-1])[None, None, :]
    tgt = jnp.sum(jnp.where(vocab_iota == targets[..., None], lg, 0.0),
                  axis=-1)
    nll = lse - tgt
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:].astype(jnp.float32)
        per_ex = (nll * mask).sum(-1) / jnp.maximum(mask.sum(-1), 1.0)
    else:
        per_ex = nll.mean(-1)
    if per_example:
        return per_ex + aux_weight * aux
    return per_ex.mean() + aux_weight * aux
