"""Parameter initialization for every architecture family.

Layer parameters are *stacked over periods*: for each position ``i`` in
``cfg.layer_pattern`` the subtree ``stack['p{i}']`` has a leading
``n_periods`` axis, so the forward pass can ``lax.scan`` over periods.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.ssm import _dt_rank


def _norm_p(cfg, d, n=None, kind=None):
    kind = kind or cfg.norm
    shape = (n, d) if n else (d,)
    p = {"scale": jnp.zeros(shape) if kind == "rmsnorm" else jnp.ones(shape)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros(shape)
    return p


class _KeyGen:
    def __init__(self, key):
        self.key = key

    def __call__(self):
        self.key, k = jax.random.split(self.key)
        return k


def _dense(kg, shape, std=0.02, n=None):
    shape = (n, *shape) if n else shape
    return jax.random.normal(kg(), shape) * std


def _attn_params(kg, cfg: ModelConfig, n: int, cross: bool = False):
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    out_std = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {
        "norm": _norm_p(cfg, D, n),
        "wq": _dense(kg, (D, H * hd), n=n),
        "wk": _dense(kg, (D, KV * hd), n=n),
        "wv": _dense(kg, (D, KV * hd), n=n),
        "wo": _dense(kg, (H * hd, D), std=out_std, n=n),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((n, H * hd))
        p["bk"] = jnp.zeros((n, KV * hd))
        p["bv"] = jnp.zeros((n, KV * hd))
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.zeros((n, hd))
        p["k_norm"] = jnp.zeros((n, hd))
    if cfg.post_norms and not cross:
        p["post_norm"] = _norm_p(cfg, D, n)
    if cfg.lora_rank and not cross:
        r = cfg.lora_rank
        p["lora_qa"] = _dense(kg, (D, r), n=n)
        p["lora_qb"] = jnp.zeros((n, r, H * hd))
        p["lora_va"] = _dense(kg, (D, r), n=n)
        p["lora_vb"] = jnp.zeros((n, r, KV * hd))
    return p


def _mlp_params(kg, cfg: ModelConfig, n: int):
    D, F = cfg.d_model, cfg.d_ff
    out_std = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {
        "norm2": _norm_p(cfg, D, n),
        "w1": _dense(kg, (D, F), n=n),
        "w2": _dense(kg, (F, D), std=out_std, n=n),
    }
    if cfg.act != "gelu_plain":
        p["w3"] = _dense(kg, (D, F), n=n)
    if cfg.post_norms:
        p["post_norm2"] = _norm_p(cfg, D, n)
    return p


def _moe_params(kg, cfg: ModelConfig, n: int):
    m = cfg.moe
    D, F, E = cfg.d_model, m.d_ff_expert, m.n_experts
    out_std = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {
        "norm2": _norm_p(cfg, D, n),
        "router": _dense(kg, (D, E), n=n),
        "w1": _dense(kg, (E, D, F), n=n),
        "w3": _dense(kg, (E, D, F), n=n),
        "w2": _dense(kg, (E, F, D), std=out_std, n=n),
    }
    if m.n_shared_experts:
        Fs = F * m.n_shared_experts
        p["sw1"] = _dense(kg, (D, Fs), n=n)
        p["sw3"] = _dense(kg, (D, Fs), n=n)
        p["sw2"] = _dense(kg, (Fs, D), std=out_std, n=n)
    return p


def _mamba_params(kg, cfg: ModelConfig, n: int):
    s = cfg.ssm
    D = cfg.d_model
    E = s.expand * D
    N = s.d_state
    r = _dt_rank(D, s)
    out_std = 0.02 / math.sqrt(2 * cfg.n_layers)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (E, 1))
    return {
        "norm": _norm_p(cfg, D, n),
        "in_proj": _dense(kg, (D, 2 * E), n=n),
        "conv_w": _dense(kg, (s.d_conv, E), std=0.2, n=n),
        "conv_b": jnp.zeros((n, E)),
        "x_proj": _dense(kg, (E, r + 2 * N), n=n),
        "dt_proj": _dense(kg, (r, E), std=r ** -0.5, n=n),
        "dt_bias": jnp.tile(jnp.log(jnp.expm1(jnp.full((E,), 0.01)))[None], (n, 1)),
        "A_log": jnp.tile(jnp.log(A)[None], (n, 1, 1)),
        "D": jnp.ones((n, E)),
        "out_proj": _dense(kg, (E, D), std=out_std, n=n),
    }


def _mlstm_params(kg, cfg: ModelConfig, n: int):
    x = cfg.xlstm
    D = cfg.d_model
    E = int(x.proj_factor_mlstm * D)
    H = x.n_heads
    dh = E // H
    out_std = 0.02 / math.sqrt(2 * cfg.n_layers)
    return {
        "norm": _norm_p(cfg, D, n),
        "up_proj": _dense(kg, (D, 2 * E), n=n),
        "wq": _dense(kg, (E, E), n=n),
        "wk": _dense(kg, (E, E), n=n),
        "wv": _dense(kg, (E, E), n=n),
        "w_i": _dense(kg, (E, H), std=0.01, n=n),
        "b_i": jnp.zeros((n, H)),
        "w_f": _dense(kg, (E, H), std=0.01, n=n),
        "b_f": jnp.full((n, H), 3.0),  # forget-gate bias -> remember
        "gn_scale": jnp.ones((n, H, dh)),
        "down_proj": _dense(kg, (E, D), std=out_std, n=n),
    }


def _slstm_params(kg, cfg: ModelConfig, n: int):
    x = cfg.xlstm
    D = cfg.d_model
    E = D
    H = x.n_heads
    dh = E // H
    F = int(x.proj_factor_slstm * E)
    F -= F % 2
    out_std = 0.02 / math.sqrt(2 * cfg.n_layers)
    return {
        "norm": _norm_p(cfg, D, n),
        "w_gates": _dense(kg, (D, 4 * E), n=n),
        "b_gates": jnp.concatenate(
            [jnp.zeros((n, E)), jnp.full((n, E), 3.0), jnp.zeros((n, 2 * E))],
            axis=-1),
        "r_gates": _dense(kg, (H, dh, 4, dh), std=dh ** -0.5, n=n),
        "up_proj": _dense(kg, (E, 2 * F), n=n),
        "down_proj": _dense(kg, (F, D), std=out_std, n=n),
    }


def _stack_params(kg, cfg: ModelConfig, pattern, n_periods: int,
                  with_cross: bool = False):
    stack = {}
    for i, (mixer, ffn) in enumerate(pattern):
        lp = {}
        if mixer in ("attn", "local_attn"):
            lp.update(_attn_params(kg, cfg, n_periods))
            if with_cross:
                lp["cross"] = dict(_attn_params(kg, cfg, n_periods, cross=True),
                                   norm=_norm_p(cfg, cfg.d_model, n_periods))
        elif mixer == "mamba":
            lp.update(_mamba_params(kg, cfg, n_periods))
        elif mixer == "mlstm":
            lp.update(_mlstm_params(kg, cfg, n_periods))
        elif mixer == "slstm":
            lp.update(_slstm_params(kg, cfg, n_periods))
        else:
            raise ValueError(mixer)
        if ffn == "dense":
            lp.update(_mlp_params(kg, cfg, n_periods))
        elif ffn == "moe":
            lp.update(_moe_params(kg, cfg, n_periods))
        stack[f"p{i}"] = lp
    return stack


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    """Initialize the full parameter pytree for ``cfg``."""
    kg = _KeyGen(key)
    params = {
        "embed": _dense(kg, (cfg.vocab, cfg.d_model)),
        "stack": _stack_params(kg, cfg, cfg.layer_pattern, cfg.n_periods,
                               with_cross=cfg.encoder is not None),
        "final_norm": _norm_p(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(kg, (cfg.d_model, cfg.vocab))
    if cfg.encoder is not None:
        params["encoder"] = {
            "stack": _stack_params(kg, cfg, (("attn", "dense"),),
                                   cfg.encoder.n_layers),
            "final_norm": _norm_p(cfg, cfg.d_model),
        }
    return jax.tree.map(lambda a: a.astype(dtype), params)


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree (no allocation) — used by the dry-run."""
    return jax.eval_shape(
        lambda: init_params(jax.random.key(0), cfg, dtype=dtype))


def param_count(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    return int(sum(math.prod(l.shape) for l in jax.tree.leaves(tree)))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only top-k + shared experts count)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    n_moe_layers = cfg.n_periods * sum(1 for _, f in cfg.layer_pattern if f == "moe")
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    inactive = n_moe_layers * per_expert * (m.n_experts - m.top_k)
    return total - inactive
