"""Composable transformer layers: norms, RoPE variants, GQA attention with
softcaps / sliding windows / qk-norm / biases, gated & plain MLPs.

All functions are pure; parameters are plain dict pytrees created in
``repro.models.init``.

Forward attention (training / prefill) runs through one dispatch point,
:func:`forward_attention`, selecting between three semantically identical
routes per ``ShardCtx.attn_backend`` (see :func:`resolve_attn_backend`):

* ``"pallas"`` — the blockwise online-softmax Pallas kernel
  (``kernels/flash_attention.py``), GQA-grouped, no [S, S] scores,
  differentiable via its recompute-based backward kernels;
* ``"online"`` — the pure-jnp online-softmax route (differentiable, carries
  no [S, S] scores either; the ``zo_dp`` sharded-training route);
* ``"dense"``  — materialized scores (q-block-chunked when ``attn_q_block``
  is set); the GSPMD-constrained reference route.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e9  # large-negative for masking (bf16-safe)


# ---------------------------------------------------------------- norms ----
def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def apply_norm(x, p, kind: str, eps: float):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"], eps)
    return layernorm(x, p["scale"], p["bias"], eps)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ----------------------------------------------------------------- RoPE ----
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float, partial: float = 1.0):
    """x: [..., S, n_heads, head_dim]; positions: [..., S] int32.

    partial < 1 rotates only the first ``partial * head_dim`` dims
    (chatglm-style "2d" rope).
    """
    hd = x.shape[-1]
    rot = int(hd * partial)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    freqs = rope_freqs(rot, theta)  # [rot/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, rot/2]
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(xr.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ------------------------------------------------------------ attention ----
def _project_qkv(x, p, cfg):
    """Return q [B,S,H,hd], k,v [B,S,KV,hd]."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if "lora_qa" in p:
        s = cfg.lora_alpha / cfg.lora_rank
        q = q + s * jnp.einsum("bsr,rh->bsh",
                               jnp.einsum("bsd,dr->bsr", x, p["lora_qa"]),
                               p["lora_qb"])
        v = v + s * jnp.einsum("bsr,rh->bsh",
                               jnp.einsum("bsd,dr->bsr", x, p["lora_va"]),
                               p["lora_vb"])
    q = q.reshape(B, S, cfg.n_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(cfg.n_heads, hd)
        k = k + p["bk"].reshape(cfg.n_kv_heads, hd)
        v = v + p["bv"].reshape(cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_attention(q, k, v, mask, cfg, ctx=None):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,KV,hd]; mask: [B|1, Sq, Sk] bool or None.

    Grouped-query layout: scores use [B, KV, G, Sq, Sk] (the ``bqkgd``
    grouping of :func:`grouped_gqa_attention`) so K/V are never repeated
    G-fold — a repeat materializes (and, tensor-parallel, all-gathers) a
    G-times-redundant K/V copy before the matmul.  The ctx head-sharding
    constraint stays: q is constrained at its full H heads, K/V at their
    stored KV heads, so tensor-parallel head sharding survives whenever the
    axis divides the respective head count (see sharding/rules.py)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    if ctx is not None:
        spec = ctx.attn_head_spec(B, Sq, H)
        if spec is not None:
            q = ctx.constrain(q, spec)
        kv_spec = ctx.attn_head_spec(B, k.shape[1], KV)
        if kv_spec is not None:
            k = ctx.constrain(k, kv_spec)
            v = ctx.constrain(v, kv_spec)
    qg = q.reshape(B, Sq, KV, G, hd)  # head h -> (kv h//G, g h%G)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = softcap(scores, cfg.attn_softcap)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(v.dtype)


def causal_mask(Sq: int, Sk: int, window: int = 0, offset: int = 0):
    """[1, Sq, Sk] causal (optionally banded) mask.

    ``offset`` is the absolute position of query 0 minus key 0 (for caches).
    """
    qi = jnp.arange(Sq)[:, None] + offset
    ki = jnp.arange(Sk)[None, :]
    m = ki <= qi
    if window:
        m &= ki > qi - window
    return m[None]


# ---------------------------------------- forward-attention dispatch ----
ATTN_BACKENDS = ("auto", "pallas", "online", "dense")

# below this the [S, S] score tile is cache/VMEM-resident and the dense
# route's single fused matmul wins; at and above it the blockwise routes
# avoid the O(S^2) materialization that dominates forward memory
ATTN_AUTO_MIN_S = 256

# without an autotune measurement, compiled hosts only *assume* the pallas
# kernel beats the online jnp route at large S: BENCH_attn-style probes
# showed the fixed-block kernel trailing online at moderate S (0.79x at
# S=256), so untuned "auto" stays on online below this
ATTN_PALLAS_MIN_S = 1024

_DIFFERENTIABLE_ATTN = contextvars.ContextVar("differentiable_attn",
                                              default=False)


@contextlib.contextmanager
def differentiable_attn():
    """Scope marking a ``jax.grad`` trace for :func:`resolve_attn_backend`
    (train/first_order, sensitivity-mask calibration, GradIP pre-training
    gradients enter it around their grad traces).  Every route is
    differentiable — the Pallas kernel carries a recompute-based backward
    (``kernels/flash_attention.py``) — so the scope no longer *forces* a
    jnp route; it selects the grad-appropriate one: under "auto" the
    kernel VJP is preferred at blockwise S because its O(S*dh) residuals
    bound backward memory where the jnp VJPs stack O(S^2)-class score
    residuals (DESIGN.md §10).  The resolve happens at trace time, so the
    choice is baked into the jitted computation."""
    tok = _DIFFERENTIABLE_ATTN.set(True)
    try:
        yield
    finally:
        _DIFFERENTIABLE_ATTN.reset(tok)


def resolve_attn_backend(backend, cfg, ctx=None, *, S: int = 0,
                         differentiable: Optional[bool] = None) -> str:
    """Map a requested forward-attention backend to 'pallas' | 'online' |
    'dense'.

    Explicit backends are honored as requested (the Pallas kernel now
    defines a VJP, so "pallas" is valid inside grad traces too).  "auto"
    resolves, in order:

    * the legacy ``ctx.online_attn`` flag -> "online";
    * a sharded mesh -> the jnp routes (the kernel carries no GSPMD
      sharding constraints): "dense" small-S, "online" blockwise;
    * S below ``ATTN_AUTO_MIN_S`` -> "dense" (the [S, S] tile is
      cache-resident and one fused matmul wins);
    * the measured ``kernels.autotune`` table, exact (op, S, head_dim, G,
      platform) key — op is "grad" inside :func:`differentiable_attn`
      scopes, "fwd" otherwise — so a populated table always picks the
      measured-fastest route, including online where pallas loses;
    * untuned grad traces -> "pallas": the recompute VJP bounds backward
      memory to O(S*dh) residuals (the jnp VJPs stack O(S^2)-class score
      residuals — the 186 MB first_order liveness peak, DESIGN.md §10);
    * untuned forwards -> "online" when interpreting (off-TPU the kernel
      runs in the Pallas interpreter, the slowest route by far) or for
      head dims off the 128-lane tile; compiled, "pallas" only from
      ``ATTN_PALLAS_MIN_S`` up — fixed-block probes showed online winning
      at moderate S, so unmeasured hosts don't assume the kernel wins."""
    backend = backend or "auto"
    if backend not in ATTN_BACKENDS:
        raise ValueError(
            f"attn backend must be one of {ATTN_BACKENDS}, got {backend!r}")
    if differentiable is None:
        differentiable = _DIFFERENTIABLE_ATTN.get()
    if backend != "auto":
        return backend
    if ctx is not None and getattr(ctx, "online_attn", False):
        return "online"  # legacy zo_dp flag, kept as an explicit route
    if ctx is not None and ctx.mesh is not None:
        return "dense" if (not S or S < ATTN_AUTO_MIN_S) else "online"
    if S and S < ATTN_AUTO_MIN_S:
        return "dense"
    from repro.kernels.ops import _default_interpret
    if S:
        from repro.kernels import autotune
        route = autotune.fastest_route(
            S, cfg.resolved_head_dim, cfg.n_heads // cfg.n_kv_heads,
            op="grad" if differentiable else "fwd")
        if route is not None:
            return route
    if differentiable:
        if not _default_interpret() and cfg.resolved_head_dim % 128:
            return "online"  # kernel tiling does not cover this head_dim
        return "pallas"
    if _default_interpret() or cfg.resolved_head_dim % 128:
        return "online"
    return "pallas" if S >= ATTN_PALLAS_MIN_S else "online"


def forward_attention(q, k, v, cfg, ctx=None, *, window: int = 0,
                      kv_mask=None, lengths=None, q_block: int = 0,
                      kv_block: int = 0, unroll: bool = False, backend=None):
    """Unified forward-attention entry: q [B,S,H,hd]; k,v [B,S,KV,hd] ->
    [B,S,H,hd], causal (optionally banded to ``window``).

    Every training / prefill attention call routes through here (the ZO
    loss forwards inherit the route through the model's ctx).  Right-padded
    batches express key validity as per-row ``lengths`` [B] and/or
    ``kv_mask`` [B, 1, Sk]; all three backends honor both."""
    B, S, H, hd = q.shape
    be = resolve_attn_backend(
        backend or (getattr(ctx, "attn_backend", None)
                    if ctx is not None else None),
        cfg, ctx, S=S)
    if be == "pallas":
        from repro.kernels.ops import flash_attention
        L = lengths
        if L is None and kv_mask is not None:
            # right-pad contract: the mask is a per-row valid key prefix
            L = kv_mask.reshape(B, S).sum(-1).astype(jnp.int32)
        out = flash_attention(q, k, v, L, window=window,
                              softcap=cfg.attn_softcap)
        return out.astype(v.dtype)
    if be == "online":
        # default q tile of 128 keeps every score tile strictly smaller
        # than [S, S] at any routed S (>= ATTN_AUTO_MIN_S under "auto")
        return online_gqa_attention(
            q, k, v, cfg, window=window,
            q_block=q_block or min(128, S),
            kv_block=min(kv_block
                         or (getattr(ctx, "kv_block", 512)
                             if ctx is not None else 512), S),
            unroll=unroll, lengths=lengths,
            kv_mask=None if kv_mask is None else kv_mask.reshape(B, S))
    if kv_mask is None and lengths is not None:
        l_arr = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                                 (B,))
        kv_mask = (jnp.arange(S)[None, :] < l_arr[:, None])[:, None, :]
    return blocked_gqa_attention(q, k, v, cfg, ctx, window=window,
                                 q_block=q_block, unroll=unroll,
                                 kv_mask=kv_mask)


def self_attention(x, p, cfg, positions, *, local: bool, mask_extra=None,
                   ctx=None, lengths=None):
    """Full training/prefill self-attention. x: [B,S,D] -> [B,S,D].

    Routes through :func:`forward_attention` (``ctx.attn_backend``);
    ``mask_extra`` — an arbitrary [B|1,S,S] mask — only has a dense
    expression and pins the dense route."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    if cfg.rope_style != "none":
        partial = cfg.rope_partial_factor if cfg.rope_style == "partial" else 1.0
        q = apply_rope(q, positions, cfg.rope_theta, partial)
        k = apply_rope(k, positions, cfg.rope_theta, partial)
    window = cfg.sliding_window if local else 0
    if mask_extra is not None:
        mask = causal_mask(S, S, window) & mask_extra
        if lengths is not None:
            l_arr = jnp.broadcast_to(
                jnp.asarray(lengths, jnp.int32).reshape(-1), (B,))
            mask = mask & (jnp.arange(S)[None, :]
                           < l_arr[:, None])[:, None, :]
        out = gqa_attention(q, k, v, mask, cfg, ctx)
    else:
        out = forward_attention(q, k, v, cfg, ctx, window=window,
                                lengths=lengths)
    return jnp.einsum("bsx,xe->bse", out.reshape(B, S, -1), p["wo"])


def blocked_gqa_attention(q, k, v, cfg, ctx, *, window: int, q_block: int,
                          unroll: bool = False, kv_mask=None):
    """Query-block-chunked causal attention: scores are materialized per
    block [B,H,q_block,Sk] instead of [B,H,S,S].  Falls back to one full
    block when q_block does not apply.

    ``kv_mask``: [B, 1, Sk] bool key-validity (right-padded prefill masks
    its pad keys here), ANDed into the causal mask."""
    B, S, H, hd = q.shape
    if not q_block or S % q_block or S <= q_block:
        mask = causal_mask(S, S, window)
        if kv_mask is not None:
            mask = mask & kv_mask
        return gqa_attention(q, k, v, mask, cfg, ctx)
    nb = S // q_block
    qb = q.reshape(B, nb, q_block, H, hd).swapaxes(0, 1)

    def blk(qi, off):
        mask = causal_mask(q_block, S, window, offset=off)
        if kv_mask is not None:
            mask = mask & kv_mask
        return gqa_attention(qi, k, v, mask, cfg, ctx)

    if unroll:
        outs = [blk(qb[i], i * q_block) for i in range(nb)]
        return jnp.concatenate(outs, axis=1)
    outs = jax.lax.map(lambda t: blk(t[0], t[1]),
                       (qb, jnp.arange(nb) * q_block))
    return outs.swapaxes(0, 1).reshape(B, S, H, hd)


def online_gqa_attention(q, k, v, cfg, *, window: int = 0,
                         q_block: int = 512, kv_block: int = 512,
                         unroll: bool = False, lengths=None, kv_mask=None):
    """Flash-style causal attention: online-softmax over KV blocks, grouped
    query (no KV repeat).  Never materializes [S, S] scores — the working
    set per (q_block, kv_block) tile is O(q_block * kv_block), so the HBM
    traffic drops from O(H*S^2) to O(S*d) (§Perf pair 2, iteration 2).

    q: [B,S,H,hd]; k,v: [B,S,KV,hd] -> [B,S,H,hd].  Semantically identical
    to gqa_attention with a causal (optionally banded) mask.

    ``S`` need not be a block multiple: inputs are zero-padded up to one
    (padded keys are masked through the key-validity stream, padded query
    rows trimmed from the output).  ``lengths`` ([B] int32) and/or
    ``kv_mask`` ([B, S] bool) mask right-padded keys, so batched
    right-padded prefill/training can take this route instead of falling
    back to dense attention.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    q_block = max(1, min(q_block, S))
    kv_block = max(1, min(kv_block, S))
    per = q_block * kv_block // math.gcd(q_block, kv_block)
    pad = (-S) % per
    kvv = None if kv_mask is None else jnp.asarray(kv_mask, bool)
    if lengths is not None:
        l_arr = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                                 (B,))
        lm = jnp.arange(S)[None, :] < l_arr[:, None]
        kvv = lm if kvv is None else (kvv & lm)
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(x, padw) for x in (q, k, v))
        if kvv is None:
            kvv = jnp.broadcast_to(jnp.arange(S + pad)[None, :] < S,
                                   (B, S + pad))
        else:
            kvv = jnp.pad(kvv, ((0, 0), (0, pad)))
    Sp = S + pad
    nq, nk = Sp // q_block, Sp // kv_block
    qg = q.reshape(B, nq, q_block, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 2, 3, 4)
    kvb = (None if kvv is None
           else kvv.reshape(B, nk, kv_block).transpose(1, 0, 2))
    ki_base = jnp.arange(kv_block)[None, :]
    qi_base = jnp.arange(q_block)[:, None]

    def q_chunk(args):
        qb, q0 = args[0], args[1]  # [B,q_block,KV,G,hd], scalar offset

        def kv_step(carry, inp):
            m, l, acc = carry
            kb, vb, k0 = inp[0], inp[1], inp[2]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, cfg.attn_softcap)
            valid = (k0 + ki_base) <= (q0 + qi_base)
            if window:
                valid &= (k0 + ki_base) > (q0 + qi_base - window)
            valid = valid[None, None, None, :, :]
            if kvb is not None:
                valid = valid & inp[3][:, None, None, None, :]  # [B,kv_block]
            s = jnp.where(valid, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            # mask p explicitly: on a fully-masked row m_new is still
            # NEG_INF and exp(s - m_new) would be 1, not 0
            p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, hd), jnp.float32)
        offs = jnp.arange(nk) * kv_block
        xs = (ks, vs, offs) if kvb is None else (ks, vs, offs, kvb)
        if unroll:
            carry = (m0, l0, a0)
            for i in range(nk):
                carry, _ = kv_step(carry, tuple(x[i] for x in xs))
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), xs)
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # [B,KV,G,q_block,hd]
        return out

    if unroll:
        outs = jnp.stack([q_chunk((qg[i], jnp.asarray(i * q_block)))
                          for i in range(nq)])
    else:
        outs = jax.lax.map(q_chunk, (qg, jnp.arange(nq) * q_block))
    # [nq,B,KV,G,q_block,hd] -> [B, nq*q_block, KV*G, hd]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp, H, hd)
    return out[:, :S].astype(v.dtype)


def self_attention_chunked(x, p, cfg, positions, *, local: bool, q_block: int,
                           unroll: bool = False, ctx=None, lengths=None):
    """Query-block-chunked causal self-attention; semantically identical to
    :func:`self_attention`, threading ``q_block`` into whichever backend
    :func:`forward_attention` resolves (the dense route chunks its scores
    per q_block, the online/pallas routes tile by it)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    if cfg.rope_style != "none":
        partial = cfg.rope_partial_factor if cfg.rope_style == "partial" else 1.0
        q = apply_rope(q, positions, cfg.rope_theta, partial)
        k = apply_rope(k, positions, cfg.rope_theta, partial)
    window = cfg.sliding_window if local else 0
    out = forward_attention(q, k, v, cfg, ctx, window=window, q_block=q_block,
                            unroll=unroll, lengths=lengths)
    return jnp.einsum("bsx,xe->bse", out.reshape(B, S, -1), p["wo"])


def bidir_attention(x, p, cfg, ctx=None):
    """Encoder (non-causal) self-attention."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    out = gqa_attention(q, k, v, None, cfg, ctx)
    return jnp.einsum("bsx,xe->bse", out.reshape(B, S, -1), p["wo"])


def cross_attention(x, enc_kv, p, cfg, ctx=None):
    """Decoder cross-attention. enc_kv: (k,v) each [B,Senc,KV,hd]."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k, v = enc_kv
    out = gqa_attention(q, k, v, None, cfg, ctx)
    return jnp.einsum("bsx,xe->bse", out.reshape(B, S, -1), p["wo"])


def encode_kv(enc_out, p, cfg):
    """Precompute cross-attention K/V from encoder output."""
    B, Se, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(B, Se, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(B, Se, cfg.n_kv_heads, hd)
    return k, v


# -------------------------------------------------- decode-mode attention ----
def grouped_gqa_attention(q, k, v, valid, cfg, ctx=None):
    """Decode attention with the query grouped per KV head — no KV repeat.

    q: [B,Sq,H,hd]; k,v: [B,W,KV,hd]; valid: [B|1,Sq,W] bool.

    ``gqa_attention`` originally repeated K/V to H heads before the
    matmul, which for a 32k decode cache materializes (and,
    tensor-parallel, all-gathers) a G-times-redundant [B,W,KV,G,hd]
    tensor (§Perf iteration 1); this grouped variant predates — and
    motivated — the same ``bqkgd`` layout now used there.  Grouping
    the *query* keeps cache-sized tensors at their stored shape;
    with the cache sequence-sharded over 'model', scores come out
    W-sharded, the softmax lowers to cheap stat all-reduces, and the output
    contraction partial-sums into one [B,KV,G,hd]-sized all-reduce."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scale = hd ** -0.5
    # bf16 operands + f32 accumulation via preferred_element_type: avoids
    # materializing cache-sized f32 converts (§Perf iteration 2) and is the
    # TPU-native MXU mode.
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cfg.attn_softcap)
    if valid is not None:
        scores = jnp.where(valid[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(v.dtype)


DECODE_BACKENDS = ("auto", "pallas", "ref")


def resolve_decode_backend(backend, cfg, ctx=None) -> str:
    """Map a requested decode-attention backend to 'pallas' | 'ref'.

    Mirrors ``core/dispatch.resolve_backend``: "auto" prefers the Pallas
    flash-decode kernel (interpret mode off-TPU, see kernels/ops.py) and
    falls back to the grouped jnp path for layouts the kernel does not
    cover — a sharded mesh (the jnp path carries the GSPMD sharding
    constraints) or, compiled on a real TPU, a head_dim off the 128-lane
    tile."""
    backend = backend or "auto"
    if backend not in DECODE_BACKENDS:
        raise ValueError(
            f"decode backend must be one of {DECODE_BACKENDS}, got {backend!r}")
    if backend != "auto":
        return backend
    if ctx is not None and ctx.mesh is not None:
        return "ref"
    from repro.kernels.ops import _default_interpret
    if not _default_interpret() and cfg.resolved_head_dim % 128:
        return "ref"
    return "pallas"


def decode_self_attention(x1, p, cfg, cache_k, cache_v, cur_pos, *,
                          local: bool, ctx=None):
    """One-token decode. x1: [B,1,D]; cache_k/v: [B,W,KV,hd] (rolling when
    local); cur_pos: scalar or per-row [B] (continuous-batching slots each
    sit at their own position).  Returns (out [B,1,D], new_k, new_v).

    Routes through the Pallas flash-decode kernel or the grouped jnp path
    per ``ctx.decode_backend`` (see :func:`resolve_decode_backend`)."""
    B = x1.shape[0]
    hd = cfg.resolved_head_dim
    W = cache_k.shape[1]
    q, k, v = _project_qkv(x1, p, cfg)  # [B,1,H,hd], [B,1,KV,hd]
    pos_vec = jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32).reshape(-1),
                               (B,))
    if cfg.rope_style != "none":
        partial = cfg.rope_partial_factor if cfg.rope_style == "partial" else 1.0
        pos = pos_vec[:, None]
        q = apply_rope(q, pos, cfg.rope_theta, partial)
        k = apply_rope(k, pos, cfg.rope_theta, partial)
    rolling = bool(local and cfg.sliding_window)
    slot = jnp.mod(pos_vec, W) if rolling else jnp.minimum(pos_vec, W - 1)
    # cast to the cache dtype BEFORE the update: rope upcasts k to f32, and
    # dynamic_update_slice would promote the *entire cache* to f32 per layer
    # (a full-cache convert round-trip; §Perf iteration 3)
    upd = jax.vmap(
        lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(c, u, s, axis=0))
    cache_k = upd(cache_k, k.astype(cache_k.dtype), slot)
    cache_v = upd(cache_v, v.astype(cache_v.dtype), slot)
    backend = resolve_decode_backend(
        getattr(ctx, "decode_backend", None) if ctx is not None else None,
        cfg, ctx)
    if backend == "pallas":
        # both cache layouts expose a per-row valid *prefix*: global caches
        # hold positions [0, pos], a full rolling buffer holds all W slots
        lengths = jnp.minimum(pos_vec + 1, W) if rolling else pos_vec + 1
        from repro.kernels.ops import flash_decode
        KV = cfg.n_kv_heads
        qg = q[:, 0].reshape(B, KV, cfg.n_heads // KV, hd)
        out = flash_decode(qg, cache_k, cache_v, lengths,
                           softcap=cfg.attn_softcap)
        out = out.reshape(B, 1, cfg.n_heads, hd).astype(cache_v.dtype)
    else:
        ki = jnp.arange(W)[None, None, :]  # [1,1,W]
        pv = pos_vec[:, None, None]
        if rolling:
            valid = (ki <= slot[:, None, None]) | (pv >= W)
        else:
            valid = ki <= pv
        out = grouped_gqa_attention(q, cache_k, cache_v, valid, cfg, ctx)
    out = jnp.einsum("bsx,xe->bse", out.reshape(B, 1, -1), p["wo"])
    return out, cache_k, cache_v


# ------------------------------------------------------------------ MLP ----
def mlp(x, p, cfg):
    if cfg.act == "gelu_plain":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    else:
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(jnp.einsum("bsd,df->bsf", x, p["w1"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["w3"])
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])
