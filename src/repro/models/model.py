"""Public model facade + per-shape input specs (incl. frontend stubs)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import decode as D
from repro.models import transformer as T
from repro.models.init import (abstract_params, active_param_count,
                               init_params, param_count)


class Model:
    """Thin stateless facade bundling config + apply functions."""

    def __init__(self, cfg: ModelConfig, ctx: T.ShardCtx = T.DEFAULT_CTX):
        self.cfg = cfg
        self.ctx = ctx

    def init(self, key, dtype=jnp.float32):
        return init_params(key, self.cfg, dtype=dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return abstract_params(self.cfg, dtype=dtype)

    def forward(self, params, batch):
        return T.forward(params, batch, self.cfg, self.ctx)

    def loss(self, params, batch, per_example: bool = False):
        return T.lm_loss(params, batch, self.cfg, self.ctx,
                         per_example=per_example)

    def prefill(self, params, batch, S_max: int = 0, lengths=None):
        return D.prefill(params, batch, self.cfg, self.ctx, S_max=S_max,
                         lengths=lengths)

    def decode_step(self, params, token, cache, active=None):
        return D.decode_step(params, token, cache, self.cfg, self.ctx,
                             active=active)

    def init_cache(self, B: int, S_max: int, dtype=jnp.bfloat16):
        return D.init_cache(self.cfg, B, S_max, dtype)

    def abstract_cache(self, B: int, S_max: int, dtype=jnp.bfloat16):
        return D.abstract_cache(self.cfg, B, S_max, dtype)

    @property
    def n_params(self):
        return param_count(self.cfg)

    @property
    def n_active_params(self):
        return active_param_count(self.cfg)


def input_specs(cfg: ModelConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a given shape.

    * train / prefill: tokens [B, S] (+ frontend embeds)
    * decode: token [B] (the cache is built separately)
    """
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        specs = {"token": sds((B,), jnp.int32)}
    else:
        specs = {"tokens": sds((B, S), jnp.int32)}
    if shape.kind != "decode":
        if cfg.frontend == "audio_stub":
            nf = cfg.encoder.n_frames if cfg.encoder else 1500
            specs["audio_embeds"] = sds((B, nf, cfg.d_model), dtype)
        elif cfg.frontend == "vision_stub":
            specs["patch_embeds"] = sds((B, cfg.n_patches, cfg.d_model), dtype)
    elif cfg.frontend == "audio_stub":
        # decode for enc-dec needs nothing extra: cross K/V live in the cache
        pass
    return specs


def concrete_inputs(cfg: ModelConfig, shape: InputShape, key=None,
                    dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """Random concrete inputs matching :func:`input_specs` (smoke tests)."""
    key = key if key is not None else jax.random.key(0)
    specs = input_specs(cfg, shape, dtype=dtype)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if s.dtype == jnp.int32:
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab)
        else:
            out[name] = jax.random.normal(k, s.shape, dtype)
    return out
