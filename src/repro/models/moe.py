"""Mixture-of-Experts FFN.

Two implementations:

* :func:`moe_dense_ref` — capacity-based one-hot dispatch (Switch-style) as a
  pure-jnp oracle; used for tiny models, decode-time token counts, and as the
  reference in tests.
* :func:`moe_sharded` — TPU-native expert-parallel path inside a
  ``jax.shard_map`` region: experts are sharded over the 'model' mesh axis,
  tokens are sharded over the batch axes and replicated over 'model'.  Each
  shard selects the (token, slot) assignments that route to its local experts
  with a fixed per-expert capacity (one-hot cumsum position assignment),
  gathers the activations, runs grouped matmuls ``ecd,edf->ecf`` (MXU
  friendly), scatter-adds the gate-weighted results and psums over 'model'.

Both return ``(y, aux_loss)`` where aux is the standard load-balance loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


def _router(x2d, router_w):
    """x2d: [T, D] -> probs [T, E] (f32)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1)


def _aux_loss(probs, topk_idx, n_experts: int):
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    T = probs.shape[0]
    onehot = jax.nn.one_hot(topk_idx, n_experts, dtype=jnp.float32)  # [T,k,E]
    f = onehot.sum(axis=(0, 1)) / (T * topk_idx.shape[1])
    P = probs.mean(axis=0)
    return n_experts * jnp.sum(f * P)


def _expert_ffn(xg, w1, w2, w3, act):
    """xg: [E, C, D]; w1/w3: [E, D, F]; w2: [E, F, D]."""
    h = jnp.einsum("ecd,edf->ecf", xg, w1)
    h = (jax.nn.silu if act == "silu" else jax.nn.gelu)(h)
    if w3 is not None:
        h = h * jnp.einsum("ecd,edf->ecf", xg, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _shared_expert(x2d, p, act):
    h = jnp.einsum("td,df->tf", x2d, p["sw1"])
    h = (jax.nn.silu if act == "silu" else jax.nn.gelu)(h)
    if "sw3" in p:
        h = h * jnp.einsum("td,df->tf", x2d, p["sw3"])
    return jnp.einsum("tf,fd->td", h, p["sw2"])


def moe_dense_ref(x, p, mcfg: MoEConfig, act: str = "silu", valid=None):
    """x: [B, S, D] -> (y, aux).  One-hot capacity dispatch (oracle).

    ``valid``: [B] or [B, S] bool token mask (right-padded serving
    batches / inactive continuous-batching slots).  With a mask, dispatch
    runs **per row**: each row gets its own capacity cumsum, its own
    capacity threshold derived from its own valid-token count, and its own
    expert buffers.  That makes a padded batched row's routing identical
    to routing that row alone at its exact length — no cross-row capacity
    contention — which is the serving bit-match contract.  ``None`` keeps
    the original batch-global dispatch (training)."""
    B, S, D = x.shape
    E, k = mcfg.n_experts, mcfg.top_k
    cf = mcfg.capacity_factor
    x2d = x.reshape(B * S, D)
    T = B * S
    probs = _router(x2d, p["router"])
    gate, idx = jax.lax.top_k(probs, k)  # [T,k]
    gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)
    aux = _aux_loss(probs, idx, E)
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # [T,k,E]

    if valid is None:
        C = max(1, math.ceil(T * k / E * cf))
        flat_oh = onehot.reshape(T * k, E)  # (token, slot) pairs, token-major
        pos = jnp.cumsum(flat_oh, axis=0) - flat_oh  # exclusive expert position
        pos = jnp.sum(pos * flat_oh, axis=-1).reshape(T, k)
        keep = pos < C
        pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
        disp = jnp.einsum("tke,tkc->tec", onehot, pos_oh)
        xg = jnp.einsum("tec,td->ecd", disp,
                        x2d.astype(jnp.float32)).astype(x.dtype)
        yg = _expert_ffn(xg, p["w1"], p["w2"], p.get("w3"), act)
        comb = jnp.einsum("tke,tkc,tk->tec", onehot, pos_oh, gate)
        y = jnp.einsum("tec,ecd->td", comb,
                       yg.astype(jnp.float32)).astype(x.dtype)
        y = y.reshape(B, S, D)
    else:
        v = jnp.broadcast_to(valid.reshape(B, -1), (B, S))
        oh = onehot.reshape(B, S, k, E) * v.astype(jnp.float32)[..., None,
                                                                None]
        # per-row exclusive capacity positions (token-major within the row)
        oh_flat = oh.reshape(B, S * k, E)
        pos = jnp.cumsum(oh_flat, axis=1) - oh_flat
        pos = jnp.sum(pos * oh_flat, axis=-1).reshape(B, S, k)
        # per-row capacity from the row's own valid length (matches the
        # global formula evaluated at T = row length); the static buffer
        # capacity bounds it from above
        Ls = v.sum(axis=1)  # [B]
        C_row = jnp.maximum(1, jnp.ceil(Ls * k / E * cf)).astype(jnp.int32)
        C = max(1, math.ceil(S * k / E * cf))
        keep = pos < C_row[:, None, None]
        pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
        disp = jnp.einsum("bske,bskc->bsec", oh, pos_oh)
        x3d = x.astype(jnp.float32).reshape(B, S, D)
        xg = jnp.einsum("bsec,bsd->becd", disp, x3d).astype(x.dtype)
        yg = jax.vmap(lambda g: _expert_ffn(g, p["w1"], p["w2"],
                                            p.get("w3"), act))(xg)
        comb = jnp.einsum("bske,bskc,bsk->bsec", oh, pos_oh,
                          gate.reshape(B, S, k))
        y = jnp.einsum("bsec,becd->bsd", comb,
                       yg.astype(jnp.float32)).astype(x.dtype)
    if "sw1" in p:
        y = y + _shared_expert(x2d, p, act).reshape(B, S, D)
    return y, aux


# ------------------------------------------------------------- sharded -----
def _moe_local(x, router_w, w1, w2, w3, shared, *, mcfg: MoEConfig, act: str,
               model_axis: str, batch_axes=()):
    """Body run per shard inside shard_map.

    x: [B_loc, S, D] (replicated over model axis);
    w1: [E_loc, D, F] (expert-sharded).
    """
    B, S, D = x.shape
    E, k = mcfg.n_experts, mcfg.top_k
    E_loc = w1.shape[0]
    m_idx = jax.lax.axis_index(model_axis)
    first = m_idx * E_loc

    x2d = x.reshape(B * S, D)
    T = B * S
    C = max(1, math.ceil(T * k / E * mcfg.capacity_factor))
    probs = _router(x2d, router_w)
    gate, idx = jax.lax.top_k(probs, k)  # [T, k]
    gate = gate / (gate.sum(-1, keepdims=True) + 1e-9)
    aux = _aux_loss(probs, idx, E)
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)

    local = idx - first  # [T,k]; valid if in [0, E_loc)
    valid = (local >= 0) & (local < E_loc)
    local_c = jnp.where(valid, local, 0)
    onehot = jax.nn.one_hot(local_c, E_loc, dtype=jnp.float32) * valid[..., None]
    flat_oh = onehot.reshape(T * k, E_loc)
    pos = jnp.cumsum(flat_oh, axis=0) - flat_oh  # [T*k, E_loc]
    pos = jnp.sum(pos * flat_oh, axis=-1).reshape(T, k)
    keep = valid & (pos < C)
    # token index routed to (local expert e, capacity slot c)
    tok_ids = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k))
    e_flat = jnp.where(keep, local_c, E_loc).reshape(-1)        # overflow -> E_loc
    c_flat = jnp.where(keep, pos, 0).astype(jnp.int32).reshape(-1)
    slot_tok = jnp.full((E_loc + 1, C), T, jnp.int32)           # T = dummy row
    slot_tok = slot_tok.at[e_flat, c_flat].set(tok_ids.reshape(-1), mode="drop")
    slot_tok = slot_tok[:E_loc]                                  # [E_loc, C]
    slot_gate = jnp.zeros((E_loc + 1, C), jnp.float32)
    slot_gate = slot_gate.at[e_flat, c_flat].set(gate.reshape(-1), mode="drop")
    slot_gate = slot_gate[:E_loc]

    x_pad = jnp.concatenate([x2d, jnp.zeros((1, D), x2d.dtype)], axis=0)
    xg = x_pad[slot_tok]  # [E_loc, C, D]
    yg = _expert_ffn(xg, w1, w2, w3, act)  # [E_loc, C, D]
    yg = yg.astype(jnp.float32) * slot_gate[..., None]
    y = jnp.zeros((T + 1, D), jnp.float32)
    y = y.at[slot_tok.reshape(-1)].add(yg.reshape(-1, D), mode="drop")[:T]
    y = jax.lax.psum(y, model_axis)
    if shared is not None:
        # shared expert is sharded on its hidden dim across the model axis
        sw1, sw2, sw3 = shared
        h = jnp.einsum("td,df->tf", x2d, sw1)
        h = (jax.nn.silu if act == "silu" else jax.nn.gelu)(h)
        if sw3 is not None:
            h = h * jnp.einsum("td,df->tf", x2d, sw3)
        ys = jnp.einsum("tf,fd->td", h, sw2)
        y = y + jax.lax.psum(ys.astype(jnp.float32), model_axis)
    return y.astype(x.dtype).reshape(B, S, D), aux


def moe_sharded(x, p, mcfg: MoEConfig, act: str, mesh, batch_axes, model_axis):
    """Expert-parallel MoE via shard_map. x: [B,S,D]. Requires gated (w3)."""
    P = jax.sharding.PartitionSpec
    xspec = P(batch_axes, None, None)
    has_shared = "sw1" in p

    def body(xx, rw, w1, w2, w3, *shared_ws):
        shared = None
        if has_shared:
            shared = (shared_ws[0], shared_ws[1],
                      shared_ws[2] if len(shared_ws) > 2 else None)
        return _moe_local(xx, rw, w1, w2, w3, shared, mcfg=mcfg, act=act,
                          model_axis=model_axis, batch_axes=batch_axes)

    in_specs = [xspec, P(None, None), P(model_axis, None, None),
                P(model_axis, None, None), P(model_axis, None, None)]
    args = [x, p["router"], p["w1"], p["w2"], p["w3"]]
    if has_shared:
        in_specs += [P(None, model_axis), P(model_axis, None)]
        args += [p["sw1"], p["sw2"]]
        if "sw3" in p:
            in_specs.append(P(None, model_axis))
            args.append(p["sw3"])

    fn = jax.shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                       out_specs=(xspec, P()), check_vma=False)
    return fn(*args)


def moe_ffn(x, p, mcfg: MoEConfig, act: str, ctx, valid=None):
    """Dispatch between the sharded and dense implementations.

    ``valid`` (decode-time token mask) only applies to the dense path; the
    sharded path is a training-forward route where every token is real."""
    if ctx is not None and ctx.use_sharded_moe and x.shape[0] >= ctx.dp_size:
        return moe_sharded(x, p, mcfg, act, ctx.mesh, ctx.batch_axes,
                           ctx.model_axis)
    return moe_dense_ref(x, p, mcfg, act, valid=valid)
