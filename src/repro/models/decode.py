"""Serving-side model application: cache init, prefill, one-token decode.

Cache layout (all leaves stacked over periods on axis 0):

* attn / local_attn: ``{'k','v': [n, B, W, KV, hd]}`` (W = window for local)
* mamba:             ``{'conv': [n,B,K-1,E], 'state': [n,B,E,N]}``
* mlstm:             ``{'C': [n,B,H,dh,dh], 'n': [n,B,H,dh], 'm': [n,B,H]}``
* slstm:             ``{'c','n','h','m': [n,B,E]}``
* cross-attn (audio): ``{'ck','cv': [n,B,Senc,KV,hd]}``

``cache['pos']`` is a per-row [B] int32 vector: the number of tokens each
sequence has absorbed.  Rows are independent — continuous-batching slots
prefill and retire at different positions — and ``decode_step(active=...)``
freezes the state (and position) of inactive slots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.transformer import (DEFAULT_CTX, ShardCtx, _ffn_fwd,
                                      _maybe_posenc, embed_input,
                                      encoder_forward, unembed)

P = jax.sharding.PartitionSpec


# --------------------------------------------------------------- init ------
def _mixer_cache(cfg: ModelConfig, mixer: str, n: int, B: int, S_max: int,
                 dtype):
    hd = cfg.resolved_head_dim
    if mixer in ("attn", "local_attn"):
        W = S_max
        if mixer == "local_attn" and cfg.sliding_window:
            W = min(S_max, cfg.sliding_window)
        c = {"k": jnp.zeros((n, B, W, cfg.n_kv_heads, hd), dtype),
             "v": jnp.zeros((n, B, W, cfg.n_kv_heads, hd), dtype)}
        if cfg.encoder is not None:
            Se = cfg.encoder.n_frames
            c["ck"] = jnp.zeros((n, B, Se, cfg.n_kv_heads, hd), dtype)
            c["cv"] = jnp.zeros((n, B, Se, cfg.n_kv_heads, hd), dtype)
        return c
    if mixer == "mamba":
        E = cfg.ssm.expand * cfg.d_model
        return {"conv": jnp.zeros((n, B, cfg.ssm.d_conv - 1, E), dtype),
                "state": jnp.zeros((n, B, E, cfg.ssm.d_state), jnp.float32)}
    if mixer == "mlstm":
        E = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
        H = cfg.xlstm.n_heads
        dh = E // H
        return {"C": jnp.zeros((n, B, H, dh, dh), jnp.float32),
                "n": jnp.zeros((n, B, H, dh), jnp.float32),
                "m": jnp.full((n, B, H), -1e30, jnp.float32)}
    if mixer == "slstm":
        E = cfg.d_model
        return {k: jnp.zeros((n, B, E), jnp.float32) for k in "cnhm"}
    raise ValueError(mixer)


def init_cache(cfg: ModelConfig, B: int, S_max: int, dtype=jnp.bfloat16):
    n = cfg.n_periods
    stack = {f"p{i}": _mixer_cache(cfg, mixer, n, B, S_max, dtype)
             for i, (mixer, _) in enumerate(cfg.layer_pattern)}
    return {"stack": stack, "pos": jnp.zeros((B,), jnp.int32)}


def abstract_cache(cfg: ModelConfig, B: int, S_max: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, B, S_max, dtype))


# -------------------------------------------------------------- decode -----
def _mixer_decode(x1, lp, cc, mixer, cfg, ctx, cur_pos):
    h = L.apply_norm(x1, lp["norm"], cfg.norm, cfg.norm_eps)
    new_cc = dict(cc)
    if mixer in ("attn", "local_attn"):
        y, nk, nv = L.decode_self_attention(
            h, lp, cfg, cc["k"], cc["v"], cur_pos,
            local=(mixer == "local_attn"), ctx=ctx)
        new_cc["k"], new_cc["v"] = nk, nv
    elif mixer == "mamba":
        y, buf, st = SSM.mamba_decode(h, lp, cfg.ssm, cc["conv"], cc["state"])
        new_cc["conv"], new_cc["state"] = buf, st
    elif mixer == "mlstm":
        y, C, nn, m = XL.mlstm_decode(h, lp, cfg.xlstm, cc["C"], cc["n"],
                                      cc["m"])
        new_cc["C"], new_cc["n"], new_cc["m"] = C, nn, m
    elif mixer == "slstm":
        y, c, nn, hh, m = XL.slstm_decode(h, lp, cfg.xlstm, cc["c"], cc["n"],
                                          cc["h"], cc["m"])
        new_cc["c"], new_cc["n"], new_cc["h"], new_cc["m"] = c, nn, hh, m
    else:
        raise ValueError(mixer)
    if cfg.post_norms and "post_norm" in lp:
        y = L.apply_norm(y, lp["post_norm"], cfg.norm, cfg.norm_eps)
    x1 = x1 + y
    if "cross" in lp and "ck" in cc:
        h = L.apply_norm(x1, lp["cross"]["norm"], cfg.norm, cfg.norm_eps)
        x1 = x1 + L.cross_attention(h, (cc["ck"], cc["cv"]), lp["cross"],
                                    cfg, ctx)
    return x1, new_cc


def decode_step(params, token, cache, cfg: ModelConfig,
                ctx: ShardCtx = DEFAULT_CTX, active=None):
    """token: [B] int32 -> (logits [B,V], new cache).

    ``active``: optional [B] bool — inactive rows (drained / empty
    continuous-batching slots) keep their cache state and position
    unchanged, so a finished request's slot is untouched while the rest of
    the batch keeps decoding.  Their logits are garbage; callers ignore
    them."""
    B = token.shape[0]
    x = params["embed"][token][:, None, :]  # [B,1,D]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    cur = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32).reshape(-1),
                           (B,))
    x = _maybe_posenc(x, cfg, offset=cur)
    # decode rows are independent requests: MoE dispatch must always run
    # per row (own capacity pool), or co-batched requests contend for
    # expert capacity and batched decode diverges from single-request
    act = (jnp.ones((B,), bool) if active is None
           else jnp.asarray(active, bool))

    def body(xx, inp):
        pp, cc = inp
        new_cc = {}
        for i, (mixer, ffn) in enumerate(cfg.layer_pattern):
            xx, new_cc[f"p{i}"] = _mixer_decode(xx, pp[f"p{i}"], cc[f"p{i}"],
                                                mixer, cfg, ctx, cur)
            xx, _ = _ffn_fwd(xx, pp[f"p{i}"], ffn, cfg, ctx, token_valid=act)
        return xx, new_cc

    x, new_stack = jax.lax.scan(body, x, (params["stack"], cache["stack"]),
                                unroll=ctx.scan_unroll)
    x = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    logits = unembed(x, params, cfg)[:, 0]
    if active is None:
        new_pos = cur + 1
    else:
        def freeze(new, old):
            a = act.reshape((1, -1) + (1,) * (new.ndim - 2))
            return jnp.where(a, new, old)

        new_stack = jax.tree.map(freeze, new_stack, cache["stack"])
        new_pos = cur + act.astype(jnp.int32)
    return logits, {"stack": new_stack, "pos": new_pos}


# ------------------------------------------------------------- prefill -----
def _fill_attn_cache(k, v, W: int, lengths=None):
    """k,v: [B,S,KV,hd] -> rolling buffer of size W aligned to slot = pos %W.

    ``lengths``: per-row valid length (right-padded prefill).  Each row's
    buffer is aligned to *its own* position stream: slot j holds the key at
    absolute position p with p % W == j and p in [max(0, len-W), len) —
    exactly where ``decode_self_attention`` will read/write next."""
    B, S, KV, hd = k.shape
    if S <= W:
        pad = W - S
        kb = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vb = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return kb, vb
    j = jnp.arange(W)[None, :]
    if lengths is None:
        start = jnp.full((B, 1), S - W, jnp.int32)
    else:
        start = jnp.maximum(lengths[:, None] - W, 0)
    p = start + jnp.mod(j - start, W)  # [B, W]
    p = jnp.minimum(p, S - 1)  # rows with len < S: pad entries, masked later
    idx = p[:, :, None, None]
    return (jnp.take_along_axis(k, idx, axis=1),
            jnp.take_along_axis(v, idx, axis=1))


def _mixer_prefill(x, lp, mixer, cfg, ctx, positions, enc_out, S_max,
                   valid=None, lengths=None):
    """Returns (x_out, cache_entry) mirroring _mixer_fwd + state capture.

    ``valid``/``lengths``: [B,S] key-validity mask and per-row lengths for
    right-padded batches (None -> every position is real)."""
    h = L.apply_norm(x, lp["norm"], cfg.norm, cfg.norm_eps)
    cc = {}
    if mixer in ("attn", "local_attn"):
        B, S, _ = h.shape
        q, k, v = L._project_qkv(h, lp, cfg)
        if cfg.rope_style != "none":
            partial = (cfg.rope_partial_factor
                       if cfg.rope_style == "partial" else 1.0)
            q = L.apply_rope(q, positions, cfg.rope_theta, partial)
            k = L.apply_rope(k, positions, cfg.rope_theta, partial)
        local = mixer == "local_attn"
        window = cfg.sliding_window if local else 0
        kv_mask = None if valid is None else valid[:, None, :]
        y = L.forward_attention(q, k, v, cfg, ctx, window=window,
                                kv_mask=kv_mask, lengths=lengths,
                                q_block=ctx.attn_q_block,
                                unroll=ctx.unroll_chunks)
        y = jnp.einsum("bsx,xe->bse", y.reshape(B, S, -1), lp["wo"])
        W = S_max
        if local and cfg.sliding_window:
            W = min(S_max, cfg.sliding_window)
        cc["k"], cc["v"] = _fill_attn_cache(k, v, W, lengths=lengths)
    elif mixer == "mamba":
        y, (buf, st) = SSM.mamba_forward(h, lp, cfg.ssm, chunk=ctx.mamba_chunk,
                                         return_state=True, valid=valid)
        cc["conv"], cc["state"] = buf, st
    elif mixer == "mlstm":
        y, (C, n, m) = XL.mlstm_forward(h, lp, cfg.xlstm, block=ctx.mlstm_block,
                                        return_state=True, valid=valid)
        cc["C"], cc["n"], cc["m"] = C, n, m
    elif mixer == "slstm":
        y, (c, n, hh, m) = XL.slstm_forward(h, lp, cfg.xlstm, return_state=True,
                                            valid=valid)
        cc["c"], cc["n"], cc["h"], cc["m"] = c, n, hh, m
    else:
        raise ValueError(mixer)
    if cfg.post_norms and "post_norm" in lp:
        y = L.apply_norm(y, lp["post_norm"], cfg.norm, cfg.norm_eps)
    x = x + y
    if enc_out is not None and "cross" in lp:
        kv = L.encode_kv(enc_out, lp["cross"], cfg)
        cc["ck"], cc["cv"] = kv
        hh = L.apply_norm(x, lp["cross"]["norm"], cfg.norm, cfg.norm_eps)
        x = x + L.cross_attention(hh, kv, lp["cross"], cfg, ctx)
    return x, cc


def prefill(params, batch, cfg: ModelConfig, ctx: ShardCtx = DEFAULT_CTX,
            S_max: int = 0, lengths=None):
    """Process the prompt; returns (last-token logits [B,V], cache).

    ``lengths``: per-row [B] int32 valid *token* counts for right-padded
    batches.  Positions stay ``arange(S)`` (right-pad keeps every real
    token at its true offset); pad keys are masked out of attention,
    recurrent mixers freeze their state past each row's length, and the
    returned logits/cache position are taken at each row's last real
    token — so a padded batched prefill is equivalent to prefilling each
    row alone at its exact length.  ``None`` means every position is real.
    """
    x = embed_input(params, batch, cfg)
    x = _maybe_posenc(x, cfg)
    B, S_total = x.shape[0], x.shape[1]
    S_max = S_max or S_total
    spec = ctx.act_spec(x.shape[0])
    if spec is not None:
        x = ctx.constrain(x, spec)
    positions = jnp.broadcast_to(jnp.arange(S_total), x.shape[:2])
    if lengths is None:
        lengths_total = jnp.full((B,), S_total, jnp.int32)
        valid = None
    else:
        # frontend prefixes (vision patches) are always-valid real positions
        extra = S_total - batch["tokens"].shape[1]
        lengths_total = jnp.asarray(lengths, jnp.int32).reshape(-1) + extra
        valid = jnp.arange(S_total)[None, :] < lengths_total[:, None]
    enc_out = None
    if cfg.encoder is not None:
        enc_out = encoder_forward(params, batch["audio_embeds"].astype(x.dtype),
                                  cfg, ctx)

    def body(xx, pp):
        new_cc = {}
        for i, (mixer, ffn) in enumerate(cfg.layer_pattern):
            xx, new_cc[f"p{i}"] = _mixer_prefill(
                xx, pp[f"p{i}"], mixer, cfg, ctx, positions, enc_out, S_max,
                valid=valid, lengths=None if valid is None else lengths_total)
            # pad tokens must also stay out of MoE capacity dispatch, or
            # they evict real tokens' expert assignments across rows
            xx, _ = _ffn_fwd(xx, pp[f"p{i}"], ffn, cfg, ctx,
                             token_valid=valid)
        if spec is not None:
            xx = ctx.constrain(xx, spec)
        return xx, new_cc

    x, stack_cache = jax.lax.scan(body, x, params["stack"],
                                  unroll=ctx.scan_unroll)
    x = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    if valid is None:
        last = x[:, -1:]
    else:
        last = jnp.take_along_axis(x, (lengths_total - 1)[:, None, None],
                                   axis=1)
    logits = unembed(last, params, cfg)[:, 0]
    return logits, {"stack": stack_cache, "pos": lengths_total}
