"""Mamba-style selective SSM block (jamba mixer).

TPU adaptation: the recurrence h_t = a_t * h_{t-1} + b_t is evaluated with
``jax.lax.associative_scan`` inside fixed-size chunks (memory O(B*Lc*E*N))
with a sequential ``lax.scan`` carrying the state across chunks — the
classical chunked-parallel selective-scan layout (no CUDA kernel needed).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig


def _dt_rank(cfg_d_model: int, scfg: SSMConfig) -> int:
    return scfg.dt_rank or math.ceil(cfg_d_model / 16)


def _causal_conv(x, w, b, buf=None):
    """Depthwise causal conv. x: [B,S,E]; w: [K,E]; buf: [B,K-1,E] history."""
    K = w.shape[0]
    if buf is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = buf.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, E]
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return y + b


def _ssm_inner(dt, B_in, C_in, x, A):
    """Materialized selective scan for one chunk.

    dt, x: [B,L,E]; B_in, C_in: [B,L,N]; A: [E,N].
    Returns (h_last [B,E,N], y [B,L,E], A_cumprod_last [B,E,N]).
    """
    a = jnp.exp(dt[..., None] * A)                       # [B,L,E,N]
    b = (dt * x)[..., None] * B_in[:, :, None, :]        # [B,L,E,N]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    aprod, bcum = jax.lax.associative_scan(combine, (a, b), axis=1)
    return aprod, bcum


def mamba_forward(x, p, scfg: SSMConfig, *, chunk: int = 64,
                  return_state: bool = False, unroll: bool = False,
                  mode: str = "scan", valid=None):
    """x: [B,S,D] -> [B,S,D] (training / prefill).

    ``valid``: [B,S] bool for right-padded prefill.  Invalid steps zero dt,
    which freezes the recurrence exactly (decay exp(0*A)=1, input dt*x*B=0)
    in every mode — the final state equals the state after the last valid
    token, and the conv history buffer is gathered per row at its own
    length.

    mode:
      * "scan"   — chunked associative scan (pure XLA; simulation default).
      * "kernel" — the Pallas selective-scan kernel (kernels/mamba_scan.py):
        VMEM-resident state, O(S*E) HBM traffic; the TPU target (interpret
        mode on CPU).  Requires S and E divisible by the kernel blocks.
      * "stub"   — dry-run traffic stand-in for the kernel: one elementwise
        pass with exactly the kernel's HBM I/O footprint (read dt/B/C/x,
        write y).  NOT the scan numerically — used only by launch/dryrun.py
        so cost_analysis models the kernel's bytes (HLO cannot see inside a
        pallas custom call); see EXPERIMENTS.md §Perf pair 3.
    """
    B, S, D = x.shape
    E = scfg.expand * D
    N = scfg.d_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs_raw, z = jnp.split(xz, 2, axis=-1)
    xs = jax.nn.silu(_causal_conv(xs_raw, p["conv_w"], p["conv_b"]))
    dbc = jnp.einsum("bse,er->bsr", xs, p["x_proj"])
    r = p["dt_proj"].shape[0]
    dt_r, B_in, C_in = jnp.split(dbc, [r, r + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"])
                         + p["dt_bias"])
    if valid is not None:
        dt = jnp.where(valid[..., None], dt, 0.0)
    lengths = None if valid is None else valid.sum(axis=1).astype(jnp.int32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [E,N]

    if mode == "kernel":
        from repro.kernels.ops import mamba_scan_op
        ys2, h_fin = mamba_scan_op(dt.astype(jnp.float32),
                                   B_in.astype(jnp.float32),
                                   C_in.astype(jnp.float32),
                                   xs.astype(jnp.float32), A)
        return _finish(ys2, xs, xs_raw, z, x, p, B, E, h_fin, return_state,
                       lengths=lengths)
    if mode == "stub":
        # kernel-footprint stand-in: reads dt/B/C/x once, writes y once
        ys2 = (dt.astype(jnp.float32) * xs.astype(jnp.float32)
               * jnp.sum(B_in.astype(jnp.float32) * C_in.astype(jnp.float32),
                         axis=-1, keepdims=True))
        h_fin = jnp.zeros((B, E, N), jnp.float32)
        return _finish(ys2, xs, xs_raw, z, x, p, B, E, h_fin, return_state,
                       lengths=lengths)

    Lc = min(chunk, S)
    n_chunks = math.ceil(S / Lc)
    pad = n_chunks * Lc - S
    def padc(t):
        return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2)) if pad else t
    dtc = padc(dt).reshape(B, n_chunks, Lc, E).swapaxes(0, 1)
    Bc = padc(B_in).reshape(B, n_chunks, Lc, N).swapaxes(0, 1)
    Cc = padc(C_in).reshape(B, n_chunks, Lc, N).swapaxes(0, 1)
    xc = padc(xs).reshape(B, n_chunks, Lc, E).swapaxes(0, 1)

    def chunk_body(h0, inp):
        dt_i, B_i, C_i, x_i = inp
        aprod, bcum = _ssm_inner(dt_i.astype(jnp.float32),
                                 B_i.astype(jnp.float32),
                                 C_i.astype(jnp.float32),
                                 x_i.astype(jnp.float32), A)
        h = aprod * h0[:, None] + bcum                    # [B,Lc,E,N]
        y = jnp.einsum("blen,bln->ble", h, C_i.astype(jnp.float32))
        return h[:, -1], y

    h0 = jnp.zeros((B, E, N), jnp.float32)
    if unroll:
        h, ylist = h0, []
        for i in range(n_chunks):
            h, yi = chunk_body(h, (dtc[i], Bc[i], Cc[i], xc[i]))
            ylist.append(yi)
        h_fin, ys = h, jnp.stack(ylist)
    else:
        h_fin, ys = jax.lax.scan(chunk_body, h0, (dtc, Bc, Cc, xc))
    y = ys.swapaxes(0, 1).reshape(B, n_chunks * Lc, E)[:, :S]
    return _finish(y, xs, xs_raw, z, x, p, B, E, h_fin, return_state,
                   lengths=lengths)


def _finish(y, xs, xs_raw, z, x, p, B, E, h_fin, return_state, lengths=None):
    """Shared mamba epilogue: skip term, gate, out-projection, state.

    ``lengths``: per-row valid length (right-padded prefill) — the conv
    history buffer then holds each row's last K-1 *valid* inputs."""
    y = y + xs.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_state:
        K = p["conv_w"].shape[0]
        pad = jnp.zeros((B, K - 1, E), xs_raw.dtype)
        xp = jnp.concatenate([pad, xs_raw], axis=1)  # [B, K-1+S, E]
        if lengths is None:
            conv_buf = xp[:, -(K - 1):]
        else:
            # xp[b, len_b + j] = xs_raw[b, len_b + j - (K-1)], zeros for j
            # reaching before the sequence start
            idx = lengths[:, None] + jnp.arange(K - 1)[None, :]
            conv_buf = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
        return out, (conv_buf, h_fin)
    return out


def mamba_decode(x1, p, scfg: SSMConfig, conv_buf, state):
    """One-token decode. x1: [B,1,D]; conv_buf: [B,K-1,E]; state: [B,E,N]."""
    B, _, D = x1.shape
    N = scfg.d_state
    xz = jnp.einsum("bsd,de->bse", x1, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    new_buf = jnp.concatenate([conv_buf[:, 1:], xs.astype(conv_buf.dtype)], axis=1)
    xs = jax.nn.silu(_causal_conv(xs, p["conv_w"], p["conv_b"], buf=conv_buf))
    dbc = jnp.einsum("bse,er->bsr", xs, p["x_proj"])
    r = p["dt_proj"].shape[0]
    dt_r, B_in, C_in = jnp.split(dbc, [r, r + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"])
                         + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * A)   # [B,E,N]
    b = (dt[:, 0] * xs[:, 0]).astype(jnp.float32)[..., None] \
        * B_in[:, 0, None, :].astype(jnp.float32)
    h = a * state + b
    y = jnp.einsum("ben,bn->be", h, C_in[:, 0].astype(jnp.float32))
    y = y + xs[:, 0].astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x1.dtype)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None]
    return out, new_buf, h
