from repro.models.decode import abstract_cache, decode_step, init_cache, prefill
from repro.models.init import (abstract_params, active_param_count,
                               init_params, param_count)
from repro.models.model import Model, concrete_inputs, input_specs
from repro.models.transformer import DEFAULT_CTX, ShardCtx, forward, lm_loss
