"""xLSTM blocks: chunk-friendly parallel mLSTM and recurrent sLSTM.

TPU adaptation: the mLSTM matrix-memory recurrence admits an attention-like
parallel form  h_t = (sum_s w_ts (q_t.k_s) v_s) / n_t  with decay weights
w_ts = exp(G_s - M_t), G_s = log i_s - F_s, F the cumulative log-forget and
M_t a running max for stabilization — i.e. pure MXU matmuls (blocked over
queries for long sequences).  The sLSTM scalar-memory recurrence is
non-associative (exponential gating with a normalizer), so it runs as a
``lax.scan`` over time with the input projections hoisted out of the loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig


# ------------------------------------------------------------------ mLSTM --
def _mlstm_parallel(q, k, v, logi, logf, block: int = 0, unroll: bool = False):
    """q,k,v: [B,S,H,dh]; logi/logf: [B,S,H]. Returns [B,S,H,dh]."""
    B, S, H, dh = q.shape
    F = jnp.cumsum(logf, axis=1)             # [B,S,H]
    G = logi - F                             # log i_s - F_s
    M = jax.lax.cummax(G, axis=1)            # running max for stability
    qf = q.astype(jnp.float32) * dh ** -0.5
    kf = k.astype(jnp.float32)

    def blk(qb, Fb_unused, Mb, offset):
        # scores for query block against all keys (causal-masked)
        s = jnp.einsum("bqhd,bshd->bhqs", qb, kf)
        logw = G.swapaxes(1, 2)[:, :, None, :] - Mb.swapaxes(1, 2)[..., None]
        qi = jnp.arange(qb.shape[1])[:, None] + offset
        ki = jnp.arange(S)[None, :]
        w = jnp.where((ki <= qi)[None, None], jnp.exp(logw), 0.0)
        sw = s * w
        num = jnp.einsum("bhqs,bshd->bqhd", sw, v.astype(jnp.float32))
        den = jnp.maximum(jnp.abs(sw.sum(-1)), 1.0).swapaxes(1, 2)[..., None]
        return num / den

    if not block or block >= S:
        return blk(qf, F, M, 0).astype(v.dtype)
    n_blocks = S // block
    qb = qf.reshape(B, n_blocks, block, H, dh).swapaxes(0, 1)
    Mb = M.reshape(B, n_blocks, block, H).swapaxes(0, 1)
    if unroll:
        ys = jnp.stack([blk(qb[i], None, Mb[i], i * block)
                        for i in range(n_blocks)])
    else:
        offs = jnp.arange(n_blocks) * block
        ys = jax.lax.map(lambda t: blk(t[0], None, t[1], t[2]), (qb, Mb, offs))
    return ys.swapaxes(0, 1).reshape(B, S, H, dh).astype(v.dtype)


def mlstm_forward(x, p, xcfg: XLSTMConfig, *, block: int = 0,
                  return_state: bool = False, unroll: bool = False,
                  valid=None):
    """mLSTM block. x: [B,S,D] -> [B,S,D].

    ``valid``: [B,S] bool for right-padded prefill.  Invalid steps get
    input gate 0 (logi = -1e30) and forget gate 1 (logf = 0), so they
    contribute nothing to the matrix memory and the final (C, n, m) state
    equals the state after the last valid token."""
    B, S, D = x.shape
    H = xcfg.n_heads
    up = jnp.einsum("bsd,de->bse", x, p["up_proj"])
    xi, z = jnp.split(up, 2, axis=-1)                 # [B,S,E] each
    E = xi.shape[-1]
    dh = E // H
    q = jnp.einsum("bse,ef->bsf", xi, p["wq"]).reshape(B, S, H, dh)
    k = jnp.einsum("bse,ef->bsf", xi, p["wk"]).reshape(B, S, H, dh)
    v = jnp.einsum("bse,ef->bsf", xi, p["wv"]).reshape(B, S, H, dh)
    logi = jnp.einsum("bse,eh->bsh", xi.astype(jnp.float32), p["w_i"]) + p["b_i"]
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", xi.astype(jnp.float32), p["w_f"]) + p["b_f"])
    if valid is not None:
        logi = jnp.where(valid[..., None], logi, -1e30)
        logf = jnp.where(valid[..., None], logf, 0.0)
    h = _mlstm_parallel(q, k, v, logi, logf, block=block, unroll=unroll)
    # per-head group norm
    hf = h.astype(jnp.float32)
    mu = hf.mean(-1, keepdims=True)
    var = hf.var(-1, keepdims=True)
    h = ((hf - mu) * jax.lax.rsqrt(var + 1e-6) * p["gn_scale"]).astype(x.dtype)
    h = h.reshape(B, S, E) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, p["down_proj"])
    if return_state:
        # final recurrent states (for prefill -> decode handoff)
        F = jnp.cumsum(logf, axis=1)
        G = logi - F
        M_S = jnp.max(G, axis=1)                              # [B,H]
        w = jnp.exp(G - M_S[:, None])                         # [B,S,H]
        kf = k.astype(jnp.float32) * dh ** -0.5
        C = jnp.einsum("bsh,bshd,bshe->bhde", w, kf, v.astype(jnp.float32))
        n = jnp.einsum("bsh,bshd->bhd", w, kf)
        m = F[:, -1] + M_S
        return out, (C, n, m)
    return out


def mlstm_decode(x1, p, xcfg: XLSTMConfig, C, n, m):
    """One-token mLSTM. C: [B,H,dh,dh]; n: [B,H,dh]; m: [B,H]."""
    B, _, D = x1.shape
    H = xcfg.n_heads
    up = jnp.einsum("bsd,de->bse", x1, p["up_proj"])
    xi, z = jnp.split(up, 2, axis=-1)
    E = xi.shape[-1]
    dh = E // H
    q = jnp.einsum("bse,ef->bsf", xi, p["wq"]).reshape(B, H, dh)
    k = jnp.einsum("bse,ef->bsf", xi, p["wk"]).reshape(B, H, dh)
    v = jnp.einsum("bse,ef->bsf", xi, p["wv"]).reshape(B, H, dh)
    logi = (jnp.einsum("be,eh->bh", xi[:, 0].astype(jnp.float32), p["w_i"])
            + p["b_i"])
    logf = jax.nn.log_sigmoid(
        jnp.einsum("be,eh->bh", xi[:, 0].astype(jnp.float32), p["w_f"]) + p["b_f"])
    m_new = jnp.maximum(logf + m, logi)
    fs = jnp.exp(logf + m - m_new)[..., None]
    is_ = jnp.exp(logi - m_new)[..., None]
    kf = k.astype(jnp.float32) * dh ** -0.5
    C_new = fs[..., None] * C + is_[..., None] * (kf[..., :, None]
                                                  * v.astype(jnp.float32)[..., None, :])
    n_new = fs * n + is_ * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhde,bhd->bhe", C_new, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, qf)), 1.0)
    h = num / den[..., None]
    mu = h.mean(-1, keepdims=True)
    var = h.var(-1, keepdims=True)
    h = ((h - mu) * jax.lax.rsqrt(var + 1e-6) * p["gn_scale"]).astype(x1.dtype)
    h = h.reshape(B, 1, E) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, p["down_proj"])
    return out, C_new, n_new, m_new


# ------------------------------------------------------------------ sLSTM --
def _slstm_cell(carry, gates_x, R, heads):
    """One sLSTM step. carry: (c,n,h,m) each [B,E]; gates_x: [B,4E] (Wx+b),
    gate-major layout (i,f,z,o); R: [H, dh, 4, dh] block-diag recurrence."""
    c, n, h, m = carry
    B, E = c.shape
    H = heads
    dh = E // H
    hh = h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hdgf->bghf", hh, R).reshape(B, 4 * E)
    gi, gf, gz, go = jnp.split(gates_x + rec, 4, axis=-1)
    m_new = jnp.maximum(gf + m, gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(gf + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(x, p, xcfg: XLSTMConfig, *, return_state: bool = False,
                  valid=None):
    """sLSTM block. x: [B,S,D] -> [B,S,D].

    ``valid``: [B,S] bool for right-padded prefill; invalid steps carry the
    previous (c, n, h, m) state through unchanged."""
    B, S, D = x.shape
    H = xcfg.n_heads
    E = p["w_gates"].shape[1] // 4
    gates_x = (jnp.einsum("bsd,dg->bsg", x, p["w_gates"]).astype(jnp.float32)
               + p["b_gates"])  # [B,S,4E]
    R = p["r_gates"]  # [H, dh, 4, dh]

    init = tuple(jnp.zeros((B, E), jnp.float32) for _ in range(4))
    if valid is None:
        def step(carry, g):
            new = _slstm_cell(carry, g, R, H)
            return new, new[2]

        fin, hs = jax.lax.scan(step, init, gates_x.swapaxes(0, 1))
    else:
        def step(carry, inp):
            g, vt = inp
            new = _slstm_cell(carry, g, R, H)
            new = tuple(jnp.where(vt[:, None], nn, oo)
                        for nn, oo in zip(new, carry))
            return new, new[2]

        fin, hs = jax.lax.scan(step, init, (gates_x.swapaxes(0, 1),
                                            valid.swapaxes(0, 1)))
    h = hs.swapaxes(0, 1)  # [B,S,E]
    # gated up/down projection (proj factor 4/3)
    u = jnp.einsum("bse,ef->bsf", h.astype(x.dtype), p["up_proj"])
    u1, u2 = jnp.split(u, 2, axis=-1)
    y = jax.nn.silu(u1) * u2
    out = jnp.einsum("bsf,fd->bsd", y, p["down_proj"])
    if return_state:
        return out, fin
    return out


def slstm_decode(x1, p, xcfg: XLSTMConfig, c, n, h, m):
    """One-token sLSTM. states: [B,E] each."""
    H = xcfg.n_heads
    gates_x = (jnp.einsum("bsd,dg->bsg", x1, p["w_gates"])[:, 0]
               .astype(jnp.float32) + p["b_gates"])
    c, n, h, m = _slstm_cell((c, n, h, m), gates_x, p["r_gates"], H)
    u = jnp.einsum("be,ef->bf", h.astype(x1.dtype), p["up_proj"])
    u1, u2 = jnp.split(u, 2, axis=-1)
    y = jax.nn.silu(u1) * u2
    out = jnp.einsum("bf,fd->bd", y, p["down_proj"])[:, None]
    return out, c, n, h, m
