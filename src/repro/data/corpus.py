"""C4-proxy pre-training corpus: generic LM sequences spanning all topics.

Used (a) to select MEERKAT's sensitivity mask (avg squared gradient of the
LM loss) and (b) as the server-held pre-training gradient in GradIP."""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import TaskSpec, _class_vocab


def pretrain_batches(spec: TaskSpec, n_batches: int, batch_size: int,
                     seed: int = 100):
    """LM batches mixing all class topics + common tokens ({'tokens': [b,S]})."""
    rng = np.random.default_rng(seed)
    cv = _class_vocab(spec)
    out = []
    for _ in range(n_batches):
        toks = np.empty((batch_size, spec.seq_len), np.int32)
        for i in range(batch_size):
            c = rng.integers(spec.n_classes)
            topic = rng.choice(cv[c], size=spec.seq_len)
            common = rng.integers(0, spec.vocab, size=spec.seq_len)
            use_common = rng.random(spec.seq_len) < 0.5
            toks[i] = np.where(use_common, common, topic)
        out.append({"tokens": toks})
    return out
