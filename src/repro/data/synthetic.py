"""Synthetic classification-LM task family.

The paper fine-tunes LLMs on GLUE/SuperGLUE classification tasks; offline we
reproduce the *distributional* structure that drives its claims: each class
has a distinct token distribution ("topic"), sequences end with a SEP token,
and the model must emit the class's verbalizer token after SEP.  Class
composition per client is what IID / Dirichlet / single-label partitioning
controls — exactly the heterogeneity axis the paper studies.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np


@dataclass(frozen=True)
class TaskSpec:
    name: str = "synth"
    vocab: int = 512
    n_classes: int = 4
    seq_len: int = 16
    topic_tokens: int = 24   # class-specific vocabulary size
    noise: float = 0.25      # probability of a common (non-topic) token
    seed: int = 0

    @property
    def sep_token(self) -> int:
        return self.vocab - 1


def _class_vocab(spec: TaskSpec):
    """Disjoint topic-token sets per class (excluding verbalizers and SEP)."""
    rng = np.random.default_rng(spec.seed)
    lo, hi = spec.n_classes, spec.vocab - 1
    pool = rng.permutation(np.arange(lo, hi))
    need = spec.n_classes * spec.topic_tokens
    assert need <= len(pool), "vocab too small for topic sets"
    return pool[:need].reshape(spec.n_classes, spec.topic_tokens)


def sample_dataset(spec: TaskSpec, n: int, seed: int = 0,
                   class_probs=None) -> Dict[str, np.ndarray]:
    """Draw n examples. Returns {'tokens': [n, S], 'label': [n]}."""
    rng = np.random.default_rng(seed)
    cv = _class_vocab(spec)
    p = (np.full(spec.n_classes, 1.0 / spec.n_classes)
         if class_probs is None else np.asarray(class_probs, np.float64))
    p = p / p.sum()
    labels = rng.choice(spec.n_classes, size=n, p=p)
    S = spec.seq_len
    toks = np.empty((n, S), np.int32)
    body = S - 1
    for i, c in enumerate(labels):
        topic = rng.choice(cv[c], size=body)
        common = rng.integers(spec.n_classes, spec.vocab - 1, size=body)
        use_common = rng.random(body) < spec.noise
        toks[i, :body] = np.where(use_common, common, topic)
        toks[i, body] = spec.sep_token
    return {"tokens": toks, "label": labels.astype(np.int32)}


def make_task_fns(model, spec: TaskSpec):
    """(loss_fn, per_example_loss_fn, eval_fn) closing over the model.

    Classification via the verbalizer-token logits at the SEP position."""
    import jax
    import jax.numpy as jnp

    C = spec.n_classes

    def _logits(params, batch):
        logits, aux = model.forward(params, {"tokens": batch["tokens"]})
        return logits[:, -1, :C], aux

    def per_example(params, batch):
        lg, aux = _logits(params, batch)
        lp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(lp, batch["label"][:, None], axis=-1)[:, 0]
        return nll + 0.01 * aux

    def loss(params, batch):
        return per_example(params, batch).mean()

    def evaluate(params, batch):
        lg, _ = _logits(params, batch)
        acc = jnp.mean((jnp.argmax(lg, -1) == batch["label"]).astype(jnp.float32))
        lp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(lp, batch["label"][:, None], axis=-1).mean()
        return {"loss": nll, "acc": acc}

    return loss, per_example, jax.jit(evaluate)
