"""Client data partitioning: IID, Dirichlet(alpha) Non-IID, single-label."""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def iid_partition(n: int, n_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 2) -> List[np.ndarray]:
    """Class-wise Dirichlet split (the paper's Non-IID protocol)."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    while True:
        buckets: List[List[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx = np.where(labels == c)[0]
            rng.shuffle(idx)
            props = rng.dirichlet([alpha] * n_clients)
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for b, part in zip(buckets, np.split(idx, cuts)):
                b.extend(part.tolist())
        if min(len(b) for b in buckets) >= min_size:
            break
    return [np.sort(np.asarray(b)) for b in buckets]


def single_label_partition(labels: np.ndarray, n_clients: int,
                           seed: int = 0) -> List[np.ndarray]:
    """Extreme Non-IID: each client holds exactly one class (round-robin)."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    out = []
    for k in range(n_clients):
        c = k % n_classes
        idx = np.where(labels == c)[0]
        sub = rng.choice(idx, size=max(2, len(idx) // max(
            1, n_clients // n_classes)), replace=False)
        out.append(np.sort(sub))
    return out


def subset(data: Dict[str, np.ndarray], idx: np.ndarray):
    return {k: v[idx] for k, v in data.items()}


def label_histogram(labels: np.ndarray, parts: List[np.ndarray],
                    n_classes: int) -> np.ndarray:
    return np.stack([np.bincount(labels[p], minlength=n_classes)
                     for p in parts])
