from repro.data.corpus import pretrain_batches
from repro.data.partition import (dirichlet_partition, iid_partition,
                                  label_histogram, single_label_partition,
                                  subset)
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
