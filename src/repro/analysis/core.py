"""Analysis framework: programs, artifacts, rules, runner, report.

A :class:`Program` is a registered hot path (``registry.py``) or fixture
(``fixtures.py``): its ``build()`` returns a :class:`Built` — a jittable
callable with concrete tiny arguments plus a ``meta`` dict carrying the
per-program rule configuration (thresholds, budgets, allowlists).

:class:`Artifacts` lazily derives what rules declare via ``needs``:
``"jaxpr"`` (``jax.make_jaxpr``), ``"hlo"`` (lower + compile +
``as_text()``), ``"runtime"`` (the built callable + args, for the
recompile trace harness).  A fixture can pre-seed any artifact through
``Built.overrides`` — e.g. synthetic HLO text for the comm-budget bad
twin, so its self-test needs no multi-device mesh.

The runner produces one JSON-stable report (``schema_version`` 1):
``results`` rows are ``(program, rule)`` pairs with ``ok``, ``findings``
(severity ``"error"`` gates the exit code, ``"warning"`` is informative)
and a ``skipped`` reason when a program can't build here or a rule doesn't
apply to it.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, List, Optional, Sequence

SCHEMA_VERSION = 1


class ProgramSkip(Exception):
    """Raised by ``Program.build`` when the program can't run in this
    process (e.g. the sharded round without enough host devices)."""


@dataclasses.dataclass
class Finding:
    rule: str
    program: str
    message: str
    severity: str = "error"          # "error" gates exit code; "warning"
    detail: Optional[dict] = None

    def to_json(self) -> dict:
        d = dict(rule=self.rule, program=self.program, message=self.message,
                 severity=self.severity)
        if self.detail:
            d["detail"] = self.detail
        return d


@dataclasses.dataclass
class Built:
    """One lowered-analyzable program instance."""
    fn: Callable                      # jittable / jitted
    args: tuple                       # concrete tiny arguments
    meta: Dict = dataclasses.field(default_factory=dict)
    overrides: Dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class Program:
    name: str
    description: str
    build: Callable[[], Built]


class Artifacts:
    """Lazily derived views of one Built program, shared across rules so
    each program traces/compiles at most once per run."""

    def __init__(self, built: Built):
        self.built = built
        self._cache = dict(built.overrides)

    def jaxpr(self):
        if "jaxpr" not in self._cache:
            import jax
            self._cache["jaxpr"] = jax.make_jaxpr(self.built.fn)(
                *self.built.args)
        return self._cache["jaxpr"]

    def compiled(self):
        if "compiled" not in self._cache:
            import jax
            fn = self.built.fn
            if not hasattr(fn, "lower"):
                fn = jax.jit(fn)
            self._cache["compiled"] = fn.lower(*self.built.args).compile()
        return self._cache["compiled"]

    def hlo(self) -> str:
        if "hlo" not in self._cache:
            self._cache["hlo"] = self.compiled().as_text()
        return self._cache["hlo"]


class Rule:
    """One invariant. ``needs`` names the artifacts the rule consumes —
    the runner only derives (and pays for) what's declared.  ``check``
    returns findings; an empty list means the invariant holds."""

    name: str = "rule"
    description: str = ""
    needs: Sequence[str] = ("jaxpr",)

    def applicable(self, built: Built) -> bool:
        return True

    def check(self, program: str, built: Built,
              artifacts: Artifacts) -> List[Finding]:
        raise NotImplementedError

    # -- helpers -----------------------------------------------------------
    def allow(self, built: Built) -> tuple:
        """Per-program allowlist for this rule: ``meta["allow"][rule]``."""
        return tuple(built.meta.get("allow", {}).get(self.name, ()))

    def finding(self, program: str, message: str, severity: str = "error",
                **detail) -> Finding:
        return Finding(self.name, program, message, severity,
                       detail or None)


def run_program(program: Program, rules: Sequence[Rule]) -> List[dict]:
    """All requested rules over one program; one result row per rule."""
    rows = []
    try:
        built = program.build()
    except ProgramSkip as e:
        return [dict(program=program.name, rule=r.name, ok=True,
                     skipped=str(e), findings=[]) for r in rules]
    artifacts = Artifacts(built)
    for rule in rules:
        row = dict(program=program.name, rule=rule.name)
        if not rule.applicable(built):
            row.update(ok=True, skipped="not applicable", findings=[])
            rows.append(row)
            continue
        findings = rule.check(program.name, built, artifacts)
        errors = [f for f in findings if f.severity == "error"]
        row.update(ok=not errors,
                   findings=[f.to_json() for f in findings])
        rows.append(row)
    return rows


def run_analysis(programs: Sequence[Program],
                 rules: Sequence[Rule]) -> dict:
    import jax
    results = []
    for program in programs:
        results.extend(run_program(program, rules))
    violations = sum(1 for r in results for f in r["findings"]
                     if f["severity"] == "error")
    return dict(
        schema_version=SCHEMA_VERSION,
        jax_version=jax.__version__,
        n_devices=jax.device_count(),
        programs=[p.name for p in programs],
        rules=[r.name for r in rules],
        results=results,
        violations=violations,
        ok=violations == 0,
    )


def write_report(report: dict, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    return path
