"""``python -m repro.analysis`` — run the hot-path static analyzer.

The sharded-round program needs a 2x2 mesh, so the host device count is
forced (``--devices``, default 8) BEFORE anything imports jax; the
actual CLI lives in ``cli.py`` and is imported only after the env is
set (the package ``__init__`` is lazy for the same reason).
"""
import os
import sys


def _preparse_devices(argv) -> int:
    for i, arg in enumerate(argv):
        if arg == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if arg.startswith("--devices="):
            return int(arg.split("=", 1)[1])
    return 8


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={_preparse_devices(argv)}")
    from repro.analysis.cli import run_cli
    return run_cli(argv)


if __name__ == "__main__":
    sys.exit(main())
