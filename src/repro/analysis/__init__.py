"""Rule-based static analysis over jaxprs and compiled HLO (DESIGN.md
§10): prove the hot-path invariants the repo's perf claims rest on —
no dense [S, S]/[K, P] intermediates, no dtype drift, no host syncs,
no steady-state retraces, collective bytes within the FL comm budget,
peak-bytes/VMEM ceilings.

Entry points: ``python -m repro.analysis`` (CLI over the registered hot
paths in ``registry.py``), :data:`ALL_RULES` / :data:`HOT_PATHS` for
programmatic use, and :func:`check_no_dense_intermediates` /
:func:`max_square_dims` as the standalone jaxpr predicates tests and
benchmarks call.

Attribute access is lazy (PEP 562) so importing ``repro.analysis`` does
not import jax — ``__main__`` must set the forced host device count
first.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "Artifacts": "core", "Built": "core", "Finding": "core",
    "Program": "core", "ProgramSkip": "core", "Rule": "core",
    "run_analysis": "core", "run_program": "core", "write_report": "core",
    "ALL_RULES": "rules", "rules_by_name": "rules",
    "check_no_dense_intermediates": "rules",
    "HOT_PATHS": "registry", "programs_by_name": "registry",
    "FIXTURES": "fixtures",
    "max_square_dims": "walk", "square_dim_findings": "walk",
    "liveness_peak_bytes": "walk", "pallas_block_records": "walk",
    "iter_eqns": "walk", "aval_bytes": "walk",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.analysis' has no attribute "
                             f"{name!r}")
    return getattr(importlib.import_module(f"repro.analysis.{mod}"), name)
