"""Known-bad / known-good fixture programs: the analyzer's self-test.

Every rule ships at least one deliberately-broken program it MUST flag
and a minimal clean twin it must pass — so the analyzer itself is
falsifiable (``python -m repro.analysis --selftest`` /
``--fixture <rule>``; tests/test_analysis.py runs the same matrix).

Fixtures are self-contained (no model stack) so a selftest failure
always means the *rule* regressed, not the repo.
"""
from __future__ import annotations

from typing import Dict, List

from repro.analysis.core import Built, Program

_S = 128


# ---------------------------------------------------- dense fixtures ------
def _dense_bad() -> Built:
    import jax.numpy as jnp

    def fn(q, k):           # materialized [S, S] score matrix
        return (jnp.einsum("sd,td->st", q, k) ** 2).sum()

    q = jnp.ones((_S, 16))
    return Built(fn, (q, q), meta=dict(seq_threshold=_S))


def _dense_good() -> Built:
    import jax.numpy as jnp

    def fn(q, k):           # same reduction, no [S, S] buffer
        return ((q * k).sum(-1) ** 2).sum()

    q = jnp.ones((_S, 16))
    return Built(fn, (q, q), meta=dict(seq_threshold=_S))


# ---------------------------------------------------- dtype fixtures ------
def _dtype_bad() -> Built:
    import jax
    import jax.numpy as jnp

    def fn(x, y):
        # bf16 reduction: accumulates in bf16 instead of f32
        return jnp.sum(x.astype(jnp.bfloat16)), y * 2.0

    # f64 avals require x64 mode, which this process keeps off — trace
    # the jaxpr under the scoped enable and hand it to the rule directly
    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(fn)(jnp.ones((8,), jnp.float32),
                                   jnp.ones((8,), jnp.float64))
    x = jnp.ones((8,), jnp.float32)
    return Built(fn, (x, x), meta=dict(runtime=False),
                 overrides={"jaxpr": jaxpr})


def _dtype_good() -> Built:
    import jax.numpy as jnp

    def fn(x, y):
        return jnp.sum(x), y * 2.0

    x = jnp.ones((8,), jnp.float32)
    return Built(fn, (x, x))


# ------------------------------------------------- host-sync fixtures -----
def _hostsync_bad() -> Built:
    import jax
    import jax.numpy as jnp

    def fn(x):
        jax.debug.print("loss={l}", l=x.sum())   # debug_callback eqn
        return x * 2.0

    return Built(fn, (jnp.ones((8,)),))


def _hostsync_good() -> Built:
    import jax.numpy as jnp

    def fn(x):
        return x * 2.0

    return Built(fn, (jnp.ones((8,)),))


# ------------------------------------------------- recompile fixtures -----
def _recompile_bad_const() -> Built:
    import jax.numpy as jnp
    import numpy as np

    table = np.arange(8192, dtype=np.float32)    # 32 KiB closure capture

    def fn(x):
        return x + jnp.asarray(table)[: x.shape[0]]

    return Built(fn, (jnp.ones((8,)),), meta=dict(runtime=False))


def _recompile_bad_retrace() -> Built:
    import jax
    import jax.numpy as jnp

    def fn(x):
        # fresh jit per call: every invocation traces + compiles again —
        # the pre-PR2 per-flush serving bug in miniature
        return jax.jit(lambda y: y * 2.0)(x)

    return Built(fn, (jnp.ones((8,)),))


def _recompile_good() -> Built:
    import jax
    import jax.numpy as jnp

    fn = jax.jit(lambda x: x * 2.0 + 1.0)
    return Built(fn, (jnp.ones((8,)),))


# ------------------------------------------------------ comm fixtures -----
_HLO_BAD = """\
ENTRY %round () -> f32[] {
  %p = f32[1000000]{0} parameter(0)
  %ag = f32[4000000]{0} all-gather(f32[1000000]{0} %p), dimensions={0}
  %ar = f32[1000000]{0} all-reduce(f32[1000000]{0} %p), to_apply=%sum
}
"""

_HLO_GOOD = """\
ENTRY %round () -> f32[] {
  %p = f32[250000]{0} parameter(0)
  %ag = f32[1000000]{0} all-gather(f32[250000]{0} %p), dimensions={0}
  %ar = f32[16]{0} all-reduce(f32[16]{0} %s), to_apply=%sum
}
"""


def _comm_bad() -> Built:
    # O(model) uplink + blown gather budget + CommLog mismatch, expressed
    # as synthetic HLO so the self-test needs no multi-device mesh
    pb = 4_000_000
    return Built(lambda: None, (), overrides={"hlo": _HLO_BAD},
                 meta=dict(comm=dict(
                     param_bytes=pb, allgather_max_bytes=3 * pb // 4,
                     other_collective_max_bytes=2 ** 16,
                     expected_up_bytes=64, commlog_up_bytes=pb)))


def _comm_good() -> Built:
    pb = 1_000_000
    return Built(lambda: None, (), overrides={"hlo": _HLO_GOOD},
                 meta=dict(comm=dict(
                     param_bytes=pb, allgather_max_bytes=4 * pb,
                     other_collective_max_bytes=2 ** 16,
                     expected_up_bytes=64, commlog_up_bytes=64)))


# ---------------------------------------------------- memory fixtures -----
def _memory_bad_peak() -> Built:
    import jax.numpy as jnp

    def fn(x):               # 64 MiB [4096, 4096] f32 intermediate
        return jnp.outer(x, x).sum()

    return Built(fn, (jnp.ones((4096,)),),
                 meta=dict(peak_bytes_budget=8 * 2 ** 20, runtime=False))


def _memory_bad_vmem() -> Built:
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def fn(x):               # 16 MiB in + 16 MiB out in one block
        return pl.pallas_call(
            kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)

    return Built(fn, (jnp.ones((2048, 2048)),), meta=dict(runtime=False))


def _memory_bad_residual_stack() -> Built:
    """The pre-recompute-VJP first_order pattern: differentiating a scan
    over query blocks stacks every block's [blk, S] softmax residuals for
    the backward — O(S^2) live bytes (the measured 186 MB peak at model
    shapes).  The budget is recompute-sized (O(S*dh), what the flash
    kernel's VJP keeps), so the stacked residuals must trip the gate."""
    import jax
    import jax.numpy as jnp

    S, blk, dh = 1024, 128, 16

    def attn_loss(q, k):
        qb = q.reshape(S // blk, blk, dh)

        def one(_, qi):
            s = qi @ k.T                       # [blk, S] scores
            p = jax.nn.softmax(s, axis=-1)     # residual the scan stacks
            return _, (p @ k).sum()

        _, outs = jax.lax.scan(one, None, qb)
        return outs.sum()

    def fn(q, k):
        return jax.grad(attn_loss)(q, k)

    q = jnp.ones((S, dh))
    # recompute-sized ceiling: O(S*dh) residuals are ~64 KiB here; the
    # stacked [S/blk, blk, S] score residuals are ~4 MiB
    return Built(fn, (q, q), meta=dict(peak_bytes_budget=2 * 2 ** 20,
                                       runtime=False))


def _memory_good() -> Built:
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def fn(x):
        y = pl.pallas_call(
            kernel, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=True)(x)
        return (y * x).sum()

    return Built(fn, (jnp.ones((128, 128)),),
                 meta=dict(peak_bytes_budget=8 * 2 ** 20))


FIXTURES: Dict[str, Dict[str, List[Program]]] = {
    "dense-materialization": dict(
        bad=[Program("fixture:dense:bad", "materialized [S,S] scores",
                     _dense_bad)],
        good=[Program("fixture:dense:good", "blockwise-style reduction",
                      _dense_good)]),
    "dtype-drift": dict(
        bad=[Program("fixture:dtype:bad", "f64 aval + bf16 reduction",
                     _dtype_bad)],
        good=[Program("fixture:dtype:good", "f32 throughout",
                      _dtype_good)]),
    "host-sync": dict(
        bad=[Program("fixture:host-sync:bad", "jax.debug.print in path",
                     _hostsync_bad)],
        good=[Program("fixture:host-sync:good", "pure fn", _hostsync_good)]),
    "recompile-hazard": dict(
        bad=[Program("fixture:recompile:bad-const",
                     "32 KiB closure constant", _recompile_bad_const),
             Program("fixture:recompile:bad-retrace",
                     "fresh jit per call", _recompile_bad_retrace)],
        good=[Program("fixture:recompile:good", "stable jitted fn",
                      _recompile_good)]),
    "comm-budget": dict(
        bad=[Program("fixture:comm:bad",
                     "O(model) uplink / blown gather budget", _comm_bad)],
        good=[Program("fixture:comm:good", "gather + scalar psum only",
                      _comm_good)]),
    "memory-ceiling": dict(
        bad=[Program("fixture:memory:bad-peak", "64 MiB dense outer",
                     _memory_bad_peak),
             Program("fixture:memory:bad-vmem",
                     "32 MiB pallas block working set", _memory_bad_vmem),
             Program("fixture:memory:bad-residual-stack",
                     "scan-stacked attention backward residuals vs a "
                     "recompute-sized budget", _memory_bad_residual_stack)],
        good=[Program("fixture:memory:good", "small blocks, small peak",
                      _memory_good)]),
}
