"""Jaxpr walkers shared by the analysis rules (and, for back-compat, by
``repro.utils.jaxpr``).

Everything here is pure structure extraction over ``jax.make_jaxpr``
output: recursion into every sub-jaxpr (scan/cond/while bodies, shard_map
and pallas_call kernels), aval byte accounting, a liveness-based peak-byte
estimate, and the generalized square-dims scan behind the no-[S, S]
attention proof.  No rule policy lives here — rules.py turns these raw
facts into findings.
"""
from __future__ import annotations

from typing import Iterator, List, Tuple

import jax
from jax.extend import core as jex_core

_JAXPR_TYPES = (jex_core.Jaxpr, jex_core.ClosedJaxpr)


def _as_jaxpr(jx):
    """Unwrap ClosedJaxpr -> Jaxpr (identity on Jaxpr)."""
    return jx.jaxpr if isinstance(jx, jex_core.ClosedJaxpr) else jx


def subjaxprs(eqn) -> List:
    """Every sub-jaxpr hanging off one equation's params (scan/while/cond
    bodies, custom_jvp/vjp closures, shard_map bodies, pallas kernels)."""
    subs = []
    for p in eqn.params.values():
        for sub in jax.tree_util.tree_leaves(
                p, is_leaf=lambda x: isinstance(x, _JAXPR_TYPES)):
            if isinstance(sub, _JAXPR_TYPES):
                subs.append(_as_jaxpr(sub))
    return subs


def iter_eqns(jaxpr, depth: int = 0) -> Iterator[Tuple[object, int]]:
    """Yield ``(eqn, depth)`` over a (Closed)Jaxpr and all sub-jaxprs."""
    jx = _as_jaxpr(jaxpr)
    for eqn in jx.eqns:
        yield eqn, depth
        for sub in subjaxprs(eqn):
            yield from iter_eqns(sub, depth + 1)


def aval_bytes(aval) -> int:
    """Bytes of one abstract value (0 for abstract tokens / opaque avals).

    PRNG-key avals report their base-array footprint via ``dtype.itemsize``
    on new-style typed keys; avals without shape/dtype count as 0.
    """
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    n = 1
    for d in shape:
        if not isinstance(d, int):   # symbolic/polymorphic dim
            return 0
        n *= d
    try:
        return n * dtype.itemsize
    except AttributeError:
        return 0


def max_square_dims(jaxpr, S: int) -> int:
    """Largest count of >= S dims on any intermediate aval, walking every
    sub-jaxpr (scan/cond bodies, pallas_call kernels).

    The no-[S, S]-intermediate proof for the blockwise attention routes
    (tests/test_attn_backends.py, benchmarks/attn_bench.py): a forward
    whose jaxpr never holds two >= S dims on one buffer cannot have
    materialized the score matrix."""
    worst = 0
    for eqn, _ in iter_eqns(jaxpr):
        for var in eqn.outvars:
            shape = getattr(var.aval, "shape", ())
            worst = max(worst, sum(1 for d in shape if isinstance(d, int)
                                   and d >= S))
    return worst


def square_dim_findings(jaxpr, S: int, limit: int = 2,
                        allow_primitives=()) -> List[dict]:
    """Every intermediate holding >= ``limit`` dims of size >= ``S``:
    the offending ``{primitive, shape, dtype, depth}`` records behind
    ``max_square_dims`` (which only reports the worst count)."""
    out = []
    for eqn, depth in iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim in allow_primitives:
            continue
        for var in eqn.outvars:
            shape = getattr(var.aval, "shape", ())
            big = sum(1 for d in shape if isinstance(d, int) and d >= S)
            if big >= limit:
                out.append(dict(primitive=prim, shape=list(shape),
                                dtype=str(getattr(var.aval, "dtype", "?")),
                                depth=depth))
    return out


def constvar_records(closed_jaxpr) -> List[dict]:
    """The jaxpr's baked-in constants: ``{shape, dtype, bytes}`` per
    constvar.  Large entries are closure captures that re-trace (and
    re-ship) whenever the enclosing Python value changes — the
    recompile-hazard rule's static signal."""
    jx = closed_jaxpr
    consts = getattr(jx, "consts", None)
    cvars = _as_jaxpr(jx).constvars
    out = []
    for i, v in enumerate(cvars):
        rec = dict(shape=list(getattr(v.aval, "shape", ())),
                   dtype=str(getattr(v.aval, "dtype", "?")),
                   bytes=aval_bytes(v.aval))
        if consts is not None and i < len(consts):
            rec["type"] = type(consts[i]).__name__
        out.append(rec)
    return out


def pallas_block_records(jaxpr) -> List[dict]:
    """Per ``pallas_call``: the kernel name and the summed byte footprint
    of its block-shaped refs (the kernel jaxpr's invars — inputs, outputs
    and scratch all appear there as ``MemRef`` avals).  That sum is the
    VMEM working set one grid step holds resident."""
    out = []
    for eqn, depth in iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        kernel = eqn.params.get("jaxpr")
        if kernel is None:
            continue
        kjx = _as_jaxpr(kernel)
        refs = [dict(shape=list(getattr(v.aval, "shape", ())),
                     dtype=str(getattr(v.aval, "dtype", "?")),
                     bytes=aval_bytes(v.aval))
                for v in list(kjx.invars) + list(kjx.outvars)]
        name = ""
        nsi = eqn.params.get("name_and_src_info")
        if nsi is not None:
            name = getattr(nsi, "name", str(nsi))
        out.append(dict(name=name, depth=depth,
                        block_bytes=sum(r["bytes"] for r in refs),
                        refs=refs))
    return out


def liveness_peak_bytes(jaxpr) -> int:
    """Straight-line liveness estimate of peak live bytes for one jaxpr.

    Walks equations in program order, allocating each eqn's outputs and
    freeing every value at its last use; sub-jaxpr peaks (scan/cond
    bodies) count as transient scratch of their enclosing equation.  This
    is an *upper-bound shape* of XLA's actual allocation (no buffer
    reuse/donation modeling) — useful as a regression gate on the order of
    magnitude, not as an exact HBM number (that is
    ``compiled.memory_analysis()``, cf. benchmarks/memory_footprint.py).
    """
    jx = _as_jaxpr(jaxpr)
    eqns = jx.eqns
    n = len(eqns)
    last_use = {}
    root = list(jx.invars) + list(jx.constvars)
    for v in root:
        last_use[v] = n            # inputs live throughout (conservative)
    for i, eqn in enumerate(eqns):
        for v in eqn.invars:
            if not isinstance(v, jex_core.Literal):
                last_use[v] = max(last_use.get(v, i), i)
    for v in jx.outvars:
        if not isinstance(v, jex_core.Literal):
            last_use[v] = n
    free_at = {}
    for v, i in last_use.items():
        free_at.setdefault(i, []).append(v)

    live = sum(aval_bytes(v.aval) for v in root)
    peak = live
    for i, eqn in enumerate(eqns):
        out_b = sum(aval_bytes(v.aval) for v in eqn.outvars)
        inner = max((liveness_peak_bytes(sub) for sub in subjaxprs(eqn)),
                    default=0)
        peak = max(peak, live + out_b + inner)
        live += out_b
        for v in free_at.get(i, []):
            live -= aval_bytes(v.aval)
    return peak
