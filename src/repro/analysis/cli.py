"""CLI body for ``python -m repro.analysis`` (and
``tools/analyze_hotpaths.py``).

Kept separate from ``__main__`` so the device-count env setup there runs
before anything imports jax.  Exit codes: 0 = all invariants hold,
1 = violations (or a failed selftest), 2 = internal analyzer error.
"""
from __future__ import annotations

import argparse
import sys
import traceback

DEFAULT_OUT = "runs/analysis/ANALYSIS.json"
SMOKE_OUT = "runs/analysis/ANALYSIS_smoke.json"


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis of the registered hot paths: jaxpr/"
                    "HLO rules proving the repo's structural invariants.")
    ap.add_argument("--all", action="store_true",
                    help="run every rule over every registered hot path "
                         "(the default when no mode flag is given)")
    ap.add_argument("--programs", default=None,
                    help="comma-separated registry subset")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset")
    ap.add_argument("--out", default=None,
                    help=f"report path (default {DEFAULT_OUT})")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: also run the fixture selftest and save "
                         "under ANALYSIS_smoke.json so the committed "
                         "artifact is never clobbered")
    ap.add_argument("--selftest", action="store_true",
                    help="check every rule flags its known-bad fixture and "
                         "passes its known-good twin, then exit")
    ap.add_argument("--fixture", default=None, metavar="RULE",
                    help="run RULE over its seeded known-bad fixture(s); "
                         "exits non-zero iff the rule (correctly) fires")
    ap.add_argument("--list", action="store_true",
                    help="list registered programs and rules, then exit")
    ap.add_argument("--devices", type=int, default=8,
                    help="host device count to force before importing jax "
                         "(the sharded round needs >= 4)")
    return ap


def _selftest(rules) -> bool:
    from repro.analysis.core import run_program
    from repro.analysis.fixtures import FIXTURES
    ok = True
    for rule in rules:
        fx = FIXTURES.get(rule.name)
        if fx is None:
            print(f"FAIL {rule.name}: no fixtures registered")
            ok = False
            continue
        for kind, want_errors in (("bad", True), ("good", False)):
            for prog in fx[kind]:
                rows = run_program(prog, [rule])
                errors = [f for r in rows for f in r["findings"]
                          if f["severity"] == "error"]
                good = bool(errors) == want_errors
                ok = ok and good
                print(f"{'ok  ' if good else 'FAIL'} {rule.name:22s} "
                      f"{prog.name:32s} errors={len(errors)} "
                      f"(want {'>=1' if want_errors else '0'})")
    print("selftest:", "ok" if ok else "FAIL")
    return ok


def run_cli(argv=None) -> int:
    a = build_parser().parse_args(argv)
    try:
        return _dispatch(a)
    except (KeyError, FileNotFoundError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    except Exception:
        traceback.print_exc()
        return 2


def _dispatch(a) -> int:
    from repro.analysis.core import run_analysis, write_report
    from repro.analysis.registry import programs_by_name
    from repro.analysis.rules import rules_by_name
    rules = rules_by_name(a.rules.split(",") if a.rules else None)

    if a.list:
        from repro.analysis.registry import HOT_PATHS
        from repro.analysis.rules import ALL_RULES
        print("programs:")
        for p in HOT_PATHS:
            print(f"  {p.name:18s} {p.description}")
        print("rules:")
        for r in ALL_RULES:
            print(f"  {r.name:22s} {r.description}")
        return 0

    if a.selftest:
        return 0 if _selftest(rules) else 1

    if a.fixture:
        from repro.analysis.fixtures import FIXTURES
        if a.fixture not in FIXTURES:
            raise KeyError(f"no fixtures for rule {a.fixture!r}; "
                           f"have {sorted(FIXTURES)}")
        programs = FIXTURES[a.fixture]["bad"]
        rules = rules_by_name([a.fixture])
    else:
        programs = programs_by_name(
            a.programs.split(",") if a.programs else None)

    report = run_analysis(programs, rules)
    for row in report["results"]:
        findings = row["findings"]
        errs = sum(1 for f in findings if f["severity"] == "error")
        if row.get("skipped"):
            status, extra = "skip", row["skipped"]
        elif errs:
            status, extra = "FAIL", f"{errs} violation(s)"
        else:
            status, extra = "ok  ", ""
        print(f"{status} {row['program']:28s} {row['rule']:22s} {extra}")
        for f in findings:
            if f["severity"] == "error":
                print(f"     - {f['message']}")

    if a.fixture:
        print(f"fixture '{a.fixture}': {report['violations']} violation(s)")
        return 1 if report["violations"] else 0

    out = a.out or (SMOKE_OUT if a.smoke else DEFAULT_OUT)
    path = write_report(report, out)
    print(f"{report['violations']} violation(s) across "
          f"{len(report['programs'])} program(s) x "
          f"{len(report['rules'])} rule(s); wrote {path}")
    if a.smoke and not _selftest(rules):
        return 1
    return 0 if report["ok"] else 1
