"""The registered hot paths: every program the repo's perf story rests
on, built with tiny concrete shapes so the full rule sweep stays
seconds-cheap on CPU.

Shape plan (shared across the LM programs): ``TINY`` with ``vocab=256``
at ``S=320`` — S then exceeds *every* non-sequence dim (d_model 64,
d_ff 128, vocab 256, n_heads 4) AND the attention auto-dispatch
threshold (``ATTN_AUTO_MIN_S`` = 256), so (a) the only way to trip the
dense-materialization rule is a genuine [S, S]-class buffer, and (b)
``backend="auto"`` resolves to the same blockwise route production
takes at scale.

Liveness budgets (``peak_bytes_budget``) are regression gates set at
roughly 2x the measured estimate of the current tree — a structural
change that doubles a hot path's working set should fail loudly, normal
drift should not.  All budgets are per-program meta, so tightening or
allowlisting is a one-line registry edit (DESIGN.md §10).
"""
from __future__ import annotations

from typing import List, Optional

from repro.analysis.core import Built, Program, ProgramSkip

S = 320              # sequence length: > vocab(256) > ATTN_AUTO_MIN_S
MiB = 2 ** 20


def _tiny_lm():
    """(cfg, model, params, space) for the LM-shaped programs."""
    import jax

    from repro.configs.tiny import TINY
    from repro.core import random_mask
    from repro.models import Model
    cfg = TINY.replace(vocab=256)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    space = random_mask(params, density=1e-2, seed=3, balanced=False)
    return cfg, model, params, space


def _tokens(*shape):
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, 256, size=shape), jnp.int32)


def build_zo_train_loop() -> Built:
    """The compiled high-frequency training burst:
    ``fl_step.make_fl_train_loop`` (T=1 MEERKAT steps in one jitted
    scan), fused flat route, 2 steps x 2 clients at S=320."""
    import jax

    from repro.core.fl_step import make_fl_train_loop
    cfg, model, params, space = _tiny_lm()
    n_steps, n_clients, b = 2, 2, 1
    loop = make_fl_train_loop(
        lambda p, bt: model.loss(p, bt, per_example=True), space,
        eps=1e-3, lr=1e-2, n_clients=n_clients, n_steps=n_steps)
    batches = {"tokens": _tokens(n_steps, n_clients * b, S)}
    return Built(
        jax.jit(loop), (params, jax.random.key(1), batches),
        meta=dict(seq_threshold=S, dyn_dims={"S": S},
                  peak_bytes_budget=48 * MiB))   # measured ~24 MB


def _round_problem():
    """Synthetic-classification round problem (mirrors
    tools/fl_mesh_parity.py): the FederatedZO server's own group program
    at its production shape class, cheap enough to also *run* one round
    for the CommLog cross-check."""
    import jax

    from repro.configs.tiny import TINY
    from repro.core import random_mask
    from repro.data.synthetic import TaskSpec, make_task_fns
    from repro.models import Model
    model = Model(TINY)
    params = model.init(jax.random.key(0))
    loss, per_example, _ = make_task_fns(model, TaskSpec())
    space = random_mask(params, density=1e-2, seed=3, balanced=False)
    return model, params, loss, space


def _group_fn(loss, space, *, T: int, eps=1e-3, lr=5e-2, sharded=False):
    """The server's client-group body (``FederatedZO._batch_run_for``):
    per-client T-step local loops under ``jax.lax.map``."""
    import jax
    import jax.numpy as jnp

    from repro.core import zo
    run = zo.make_local_run(loss, space, eps, lr, n_dirs=1,
                            backend="ref", sharded=sharded)

    def group(params, keys, batches):
        zeros = jnp.zeros((space.n,), jnp.float32)
        return jax.lax.map(lambda b: run(params, keys, b, zeros), batches)

    return group


def build_fl_round() -> Built:
    """Unsharded ``FederatedZO`` round group: K=4 clients x T=2 local
    steps over the synthetic task."""
    import jax
    model, params, loss, space = _round_problem()
    K, T, b = 4, 2, 8
    group = _group_fn(loss, space, T=T)
    keys = jax.random.split(jax.random.key(2), T)
    batches = {"tokens": _tokens(K, T, b, 16),
               "label": _tokens(K, T, b) % 4}
    return Built(
        jax.jit(group), (params, keys, batches),
        meta=dict(dyn_dims={"K": K},
                  peak_bytes_budget=8 * MiB))    # measured ~2.9 MB


def build_fl_round_sharded() -> Built:
    """The sharded round: the same group body under
    ``FLShardPlan.shard_group`` on a 2x2 mesh (ZeRO-3 parameter gather at
    round entry, clients over the mesh batch axes).  Also runs one live
    ``FederatedZO`` round on the plan to cross-check ``CommLog``
    accounting against the protocol's 4*K*T*n_dirs bytes."""
    import jax

    if jax.device_count() < 4:
        raise ProgramSkip(
            "needs >= 4 devices (run `python -m repro.analysis`, which "
            "forces host devices before importing jax)")

    import numpy as np

    from repro.configs.base import FLConfig
    from repro.core import Client, FederatedZO
    from repro.data.partition import dirichlet_partition, subset
    from repro.data.synthetic import TaskSpec, sample_dataset
    from repro.sharding.fl import make_fl_plan
    model, params, loss, space = _round_problem()
    plan = make_fl_plan(spec="2x2")
    K, T, b = 4, 2, 8
    group = _group_fn(loss, space, T=T, sharded=True)
    keys = jax.random.split(jax.random.key(2), T)
    batches = {"tokens": _tokens(K, T, b, 16),
               "label": _tokens(K, T, b) % 4}
    fn = jax.jit(plan.shard_group(group, batches, K, out_ndims=(2, 2)))
    args = (plan.place_params(params), plan.place_replicated(keys),
            plan.place_client_batches(batches, K))

    # live round on the same plan: the protocol's byte accounting
    fl = FLConfig(n_clients=K, local_steps=T, lr=5e-2, eps=1e-3, seed=0,
                  zo_backend="ref")
    train = sample_dataset(TaskSpec(), 256, seed=1)
    parts = dirichlet_partition(train["label"], K, 0.5, seed=0)
    clients = [Client(k, subset(train, p), b) for k, p in enumerate(parts)]
    srv = FederatedZO(loss, params, space, fl, clients, plan=plan)
    srv.run_round()
    param_bytes = int(sum(np.prod(p.shape) * p.dtype.itemsize
                          for p in jax.tree.leaves(params)))
    return Built(
        fn, args,
        meta=dict(
            dyn_dims={"K": K},
            peak_bytes_budget=8 * MiB,           # measured ~3.3 MB
            comm=dict(
                param_bytes=param_bytes,
                # one ZeRO-3 gather of the weights per round body; 3x
                # covers the reverse scatter + async-pair double counting
                allgather_max_bytes=3 * param_bytes,
                # uplink-class traffic: deltas [K, n] + gs [K, T] + slop,
                # still ~100x under one model copy
                other_collective_max_bytes=8 * K * (space.n + T) + 2 ** 16,
                expected_up_bytes=4 * K * T * getattr(fl, "n_dirs", 1),
                commlog_up_bytes=int(srv.comm.up_bytes))))


def build_ckpt_roundtrip() -> Built:
    """The fault-tolerance save/restore round trip
    (``checkpoint/state.py``): a live ``FederatedZO`` server runs a
    round, snapshots, and restores into a fresh twin; the analyzed
    program is the round group *as driven by restored parameters*, so
    the rule sweep (dtype drift, host syncs, dense materialization,
    liveness) covers the resume path the kill-recover drill exercises.
    Restore fidelity is asserted here at build time — a checkpoint that
    loses bits must fail the sweep, not just the e2e drill."""
    import os
    import tempfile

    import jax
    import numpy as np

    from repro.configs.base import FLConfig
    from repro.core import Client, FederatedZO
    from repro.data.partition import dirichlet_partition, subset
    from repro.data.synthetic import TaskSpec, sample_dataset
    model, params, loss, space = _round_problem()
    K, T, b = 4, 2, 8
    fl = FLConfig(n_clients=K, local_steps=T, lr=5e-2, eps=1e-3, seed=0,
                  zo_backend="ref")
    train = sample_dataset(TaskSpec(), 256, seed=1)
    parts = dirichlet_partition(train["label"], K, 0.5, seed=0)

    def mk():
        clients = [Client(k, subset(train, p), b)
                   for k, p in enumerate(parts)]
        return FederatedZO(loss, params, space, fl, clients)

    srv = mk()
    srv.run_round()
    twin = mk()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        srv.save_checkpoint(path)
        twin.load_checkpoint(path)
    for a, c in zip(jax.tree.leaves(srv.params), jax.tree.leaves(twin.params)):
        if not np.array_equal(np.asarray(a), np.asarray(c)):
            raise AssertionError("checkpoint round trip lost parameter bits")

    group = _group_fn(loss, space, T=T)
    keys = jax.random.split(jax.random.key(2), T)
    batches = {"tokens": _tokens(K, T, b, 16),
               "label": _tokens(K, T, b) % 4}
    return Built(
        jax.jit(group), (twin.params, keys, batches),
        meta=dict(dyn_dims={"K": K},
                  peak_bytes_budget=8 * MiB))     # same body as fl_round


def build_prefill() -> Built:
    """``models/decode.prefill`` — the serving admission path: right-
    padded B=2 prompt batch with per-row lengths at S=320."""
    import jax
    import jax.numpy as jnp

    from repro.models import decode as D
    cfg, model, params, _ = _tiny_lm()
    ctx = model.ctx

    def fn(p, batch, lengths):
        return D.prefill(p, batch, cfg, ctx, S_max=S, lengths=lengths)

    batch = {"tokens": _tokens(2, S)}
    lengths = jnp.asarray([S, 200], jnp.int32)
    return Built(
        jax.jit(fn), (params, batch, lengths),
        meta=dict(seq_threshold=S, dyn_dims={"S": S},
                  peak_bytes_budget=24 * MiB))   # measured ~11 MB


def build_decode_burst() -> Built:
    """The continuous-batching engine's compiled decode burst
    (``ContinuousBatchingEngine._decode_fn``), tailed variant: 4 steps
    over 2 slots against an S_max=320 cache — the steady-state serving
    inner loop."""
    import jax.numpy as jnp

    from repro.serving.engine import ContinuousBatchingEngine
    cfg, model, params, _ = _tiny_lm()
    eng = ContinuousBatchingEngine(model, params, max_slots=2, S_max=S,
                                   bucket=16)
    fn = eng._decode_fn(4, True)
    remaining = jnp.asarray([3, 2], jnp.int32)
    return Built(
        fn, (params, eng.last_logits, eng.cache, remaining),
        meta=dict(seq_threshold=S, dyn_dims={"S_max": S},
                  peak_bytes_budget=8 * MiB))    # measured ~3.8 MB


def build_first_order() -> Built:
    """``train/first_order.make_train_step`` — the backprop baseline the
    roofline compares against (and the mask-calibration gradient path)."""
    from repro.train.first_order import make_train_step
    cfg, model, params, _ = _tiny_lm()
    init, step = make_train_step(lambda p, b: model.loss(p, b), lr=1e-3)
    batch = {"tokens": _tokens(2, S)}
    return Built(
        step, (params, init(params), batch),
        # measured ~17.3 MB now that the grad trace routes through the
        # flash-attention kernel's recompute-based VJP (O(S*dh) residuals:
        # only O and the per-row logsumexp survive the forward).  The old
        # differentiable-online route stacked blockwise score residuals in
        # its scan-over-blocks VJP — ~186 MB at these shapes, the pattern
        # the memory-ceiling bad fixture now pins down — so this budget
        # both gates the baseline from silently growing and proves the
        # recompute backward holds the paper's ZO-memory comparison honest.
        meta=dict(seq_threshold=S, dyn_dims={"S": S},
                  peak_bytes_budget=36 * MiB))


HOT_PATHS = (
    Program("zo_train_loop",
            "fl_step.make_fl_train_loop: jitted T=1 MEERKAT burst",
            build_zo_train_loop),
    Program("fl_round",
            "FederatedZO round group (lax.map clients), unsharded",
            build_fl_round),
    Program("fl_round_sharded",
            "FederatedZO round group under FLShardPlan.shard_group (2x2)",
            build_fl_round_sharded),
    Program("ckpt_roundtrip",
            "checkpoint save/restore round trip driving the round group",
            build_ckpt_roundtrip),
    Program("prefill",
            "models/decode.prefill: right-padded serving admission",
            build_prefill),
    Program("decode_burst",
            "ContinuousBatchingEngine._decode_fn: compiled decode burst",
            build_decode_burst),
    Program("first_order",
            "train/first_order.make_train_step: backprop baseline",
            build_first_order),
)


def programs_by_name(names: Optional[List[str]] = None) -> List[Program]:
    table = {p.name: p for p in HOT_PATHS}
    if names is None:
        return list(HOT_PATHS)
    missing = [n for n in names if n not in table]
    if missing:
        raise KeyError(f"unknown program(s) {missing}; "
                       f"have {sorted(table)}")
    return [table[n] for n in names]
