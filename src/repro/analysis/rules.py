"""The rule catalog (DESIGN.md §10): six invariants over every registered
hot path.

Each rule reads per-program configuration from ``Built.meta``:

* ``seq_threshold`` — the S for the dense-materialization scan (must
  exceed every non-sequence dim of the program, so only a genuine
  [S, S]-class buffer trips it); absent -> rule skipped.
* ``dense_limit`` — how many >= S dims constitute a violation (default 2).
* ``allow`` — ``{rule_name: (primitive, ...)}`` allowlists; an allowlisted
  primitive's outputs are exempt (document why at the registry site).
* ``const_bytes_limit`` — recompile-hazard constvar size gate (default
  4 KiB: PRNG folds and iota helpers stay under it, a baked weight or
  position table does not).
* ``dyn_dims`` — ``{name: value}`` dims the program would re-trace on
  (bucket widths, S); scalar literals equal to one are warned about.
* ``runtime`` — False disables the trace-count harness (abstract args).
* ``comm`` — comm-budget configuration (presence enables the rule):
  ``param_bytes``, ``allgather_max_bytes``, ``other_collective_max_bytes``
  and optionally ``expected_up_bytes`` + ``commlog_up_bytes`` for the
  CommLog cross-check.
* ``peak_bytes_budget`` — liveness-estimate ceiling (absent -> estimate
  reported as info only).
* ``arch`` / ``vmem_budget_bytes`` — VMEM-fit budget for pallas_call
  block working sets (default the conservative ~16 MiB/core of the
  Pallas guide).
"""
from __future__ import annotations

from typing import List

from repro.analysis.core import Built, Finding, Rule
from repro.analysis.walk import (constvar_records, iter_eqns,
                                 liveness_peak_bytes, pallas_block_records,
                                 square_dim_findings)

MAX_REPORTED = 8          # cap repeated findings per (rule, program)

# per-arch VMEM budgets for one pallas_call block working set (bytes).
# "tpu" is the conservative ~16 MiB/core floor; newer parts have more.
VMEM_BUDGETS = {"tpu": 16 * 2 ** 20, "tpu_v5e": 128 * 2 ** 20}

F64_DTYPES = ("float64", "complex128")
LOWP_DTYPES = ("bfloat16", "float16")
# reductions whose accumulator dtype follows the (low-precision) output
# aval — the kernels deliberately contract these to f32 (zo_update /
# flash_attention keep f32 VMEM accumulators), so a low-precision aval
# here means silently lossy accumulation.
REDUCE_PRIMS = ("reduce_sum", "cumsum", "dot_general", "add_any",
                "reduce_window_sum", "reduce_prod")
HOST_SYNC_PRIMS = ("infeed", "outfeed")


def check_no_dense_intermediates(jaxpr, S: int, limit: int = 2,
                                 allow_primitives=()) -> List[dict]:
    """The analyzer's dense-materialization scan as a standalone predicate
    (what tests/test_attn_backends.py and benchmarks/attn_bench.py call):
    returns the offending ``{primitive, shape, dtype}`` records — empty
    means no intermediate holds ``limit`` dims of size >= ``S``."""
    return square_dim_findings(jaxpr, S, limit=limit,
                               allow_primitives=allow_primitives)


class DenseMaterializationRule(Rule):
    """No intermediate may hold >= ``dense_limit`` dims of size >=
    ``seq_threshold`` — the generalized no-[S, S] / no-[K, P] buffer
    proof.  A blockwise attention forward that never holds two >= S dims
    on one buffer cannot have materialized the score matrix; a federated
    round that never holds [K, n_params] cannot have densified per-client
    model copies."""

    name = "dense-materialization"
    description = "no [S,S]/[K,P]-class dense intermediates"
    needs = ("jaxpr",)

    def applicable(self, built: Built) -> bool:
        return built.meta.get("seq_threshold") is not None

    def check(self, program, built, artifacts):
        S = built.meta["seq_threshold"]
        limit = built.meta.get("dense_limit", 2)
        recs = check_no_dense_intermediates(
            artifacts.jaxpr(), S, limit=limit,
            allow_primitives=self.allow(built))
        return [self.finding(
            program, f"{r['primitive']} materializes {r['dtype']}"
            f"{r['shape']} ({limit}+ dims >= {S})", **r)
            for r in recs[:MAX_REPORTED]]


class DtypeDriftRule(Rule):
    """No f64 aval anywhere (a single Python-float promotion under x64
    multiplies every buffer it touches by 2x and falls off the TPU fast
    path), and no f16/bf16-accumulated reduction — the kernels contract
    reductions to f32 VMEM accumulators, so a low-precision reduce aval
    is silently lossy summation."""

    name = "dtype-drift"
    description = "no f64 avals; no f16/bf16 reduction accumulation"
    needs = ("jaxpr",)

    def check(self, program, built, artifacts):
        allow = self.allow(built)
        out: List[Finding] = []
        jx = artifacts.jaxpr()
        for aval in getattr(jx, "in_avals", []):
            if str(getattr(aval, "dtype", "")) in F64_DTYPES:
                out.append(self.finding(
                    program, f"f64 input aval {aval}", dtype=str(aval.dtype)))
        for eqn, depth in iter_eqns(jx):
            prim = eqn.primitive.name
            if prim in allow:
                continue
            for var in eqn.outvars:
                dt = str(getattr(var.aval, "dtype", ""))
                if dt in F64_DTYPES:
                    out.append(self.finding(
                        program, f"{prim} produces {dt} "
                        f"{list(getattr(var.aval, 'shape', ()))}",
                        primitive=prim, dtype=dt, depth=depth))
                elif dt in LOWP_DTYPES and prim in REDUCE_PRIMS:
                    out.append(self.finding(
                        program, f"{prim} accumulates in {dt} "
                        f"(cast operand or set preferred_element_type=f32)",
                        primitive=prim, dtype=dt, depth=depth))
        return out[:MAX_REPORTED]


class HostSyncRule(Rule):
    """No host round-trips inside jitted hot paths: ``pure_callback`` /
    ``io_callback`` / ``debug_callback`` (jax.debug.print) equations and
    infeed/outfeed all serialize the device stream against Python —
    at decode-step or ZO-step granularity one stray print costs more
    than the step."""

    name = "host-sync"
    description = "no callbacks / infeed / outfeed in jitted paths"
    needs = ("jaxpr",)

    def check(self, program, built, artifacts):
        allow = self.allow(built)
        out = []
        for eqn, depth in iter_eqns(artifacts.jaxpr()):
            prim = eqn.primitive.name
            if prim in allow:
                continue
            if "callback" in prim or prim in HOST_SYNC_PRIMS:
                out.append(self.finding(
                    program, f"host-sync primitive '{prim}' in jitted path",
                    primitive=prim, depth=depth))
        return out[:MAX_REPORTED]


class RecompileHazardRule(Rule):
    """Three escalating signals that a hot path re-traces or re-ships:

    1. (error) constvars above ``const_bytes_limit`` — big closure
       captures are re-hashed every call and re-trace whenever the Python
       value is rebuilt (the pre-PR2 per-flush serving bug).
    2. (warning) scalar int literals equal to a declared dynamic dim —
       a baked ``S``/bucket width that will fork the compile cache.
    3. (error) the trace-count harness: call the built fn twice with the
       same concrete args under ``jax_log_compiles`` — any XLA compile on
       the second call means steady-state serving/training re-traces.
    """

    name = "recompile-hazard"
    description = "no big baked constants; no steady-state retrace"
    needs = ("jaxpr", "runtime")

    def check(self, program, built, artifacts):
        out: List[Finding] = []
        limit = built.meta.get("const_bytes_limit", 4096)
        for rec in constvar_records(artifacts.jaxpr()):
            if rec["bytes"] > limit:
                out.append(self.finding(
                    program, f"baked-in constant {rec['dtype']}"
                    f"{rec['shape']} ({rec['bytes']} B > {limit} B): "
                    f"closure capture re-traces when rebuilt", **rec))
        out.extend(self._literal_warnings(program, built, artifacts))
        if built.meta.get("runtime", True):
            n = self._second_call_compiles(built)
            if n:
                out.append(self.finding(
                    program, f"{n} XLA compile(s) on a repeat call with "
                    f"identical arguments: the hot path re-traces at "
                    f"steady state", compiles=n))
        return out

    def _literal_warnings(self, program, built, artifacts):
        dyn = built.meta.get("dyn_dims") or {}
        if not dyn:
            return []
        from jax.extend import core as jex_core
        hits = []
        values = {v: k for k, v in dyn.items()}
        for eqn, _ in iter_eqns(artifacts.jaxpr()):
            for v in eqn.invars:
                if (isinstance(v, jex_core.Literal)
                        and isinstance(v.val, int) and v.val in values):
                    hits.append((eqn.primitive.name, v.val))
        return [self.finding(
            program, f"scalar literal {val} (= dyn dim "
            f"'{values[val]}') baked into {prim}: changing it re-traces",
            severity="warning", primitive=prim, value=val)
            for prim, val in hits[:3]]

    @staticmethod
    def _second_call_compiles(built: Built) -> int:
        import logging

        import jax
        jax.block_until_ready(built.fn(*built.args))   # warm-up call
        events = []

        class _Counter(logging.Handler):
            def emit(self, record):
                if "Finished XLA compilation" in record.getMessage():
                    events.append(record.getMessage())

        logger = logging.getLogger("jax._src.dispatch")
        pxla = logging.getLogger("jax._src.interpreters.pxla")
        handler = _Counter(logging.DEBUG)
        old_propagate = (logger.propagate, pxla.propagate)
        old_flag = jax.config.jax_log_compiles
        logger.addHandler(handler)
        logger.propagate = pxla.propagate = False    # count quietly
        jax.config.update("jax_log_compiles", True)
        try:
            jax.block_until_ready(built.fn(*built.args))
        finally:
            jax.config.update("jax_log_compiles", old_flag)
            logger.removeHandler(handler)
            logger.propagate, pxla.propagate = old_propagate
        return len(events)


class CommBudgetRule(Rule):
    """The paper's headline invariant, structurally: uplink stays
    O(seeds + scalars), never O(model).  On the compiled sharded round the
    only model-sized collective allowed is the plan's ZeRO-3 parameter
    all-gather (bounded by ``allgather_max_bytes``); everything else must
    fit ``other_collective_max_bytes``.  When the builder ran a live
    round, ``commlog_up_bytes`` must equal the protocol's
    4*K*T*n_dirs-byte accounting and stay far under one model."""

    name = "comm-budget"
    description = "collective bytes: gather <= plan budget, uplink O(scalars)"
    needs = ("hlo",)

    def applicable(self, built: Built) -> bool:
        return bool(built.meta.get("comm"))

    def check(self, program, built, artifacts):
        from repro.launch.hlo_tools import collective_bytes
        comm = built.meta["comm"]
        coll = collective_bytes(artifacts.hlo())
        out = []
        ag = coll.get("all-gather", 0.0)
        others = sum(v for k, v in coll.items() if k != "all-gather")
        ag_max = comm.get("allgather_max_bytes")
        if ag_max is not None and ag > ag_max:
            out.append(self.finding(
                program, f"all-gather bytes {ag:.0f} exceed the plan's "
                f"parameter-gather budget {ag_max:.0f}", bytes=ag,
                budget=ag_max, collectives=coll))
        other_max = comm.get("other_collective_max_bytes")
        if other_max is not None and others > other_max:
            out.append(self.finding(
                program, f"non-gather collective bytes {others:.0f} exceed "
                f"the O(seeds+scalars) budget {other_max:.0f}",
                bytes=others, budget=other_max, collectives=coll))
        up = comm.get("commlog_up_bytes")
        expected = comm.get("expected_up_bytes")
        if up is not None and expected is not None and up != expected:
            out.append(self.finding(
                program, f"CommLog uplink {up} B != protocol accounting "
                f"{expected} B (4*K*T*n_dirs)", up=up, expected=expected))
        pb = comm.get("param_bytes")
        if up is not None and pb is not None and up * 8 > pb:
            out.append(self.finding(
                program, f"uplink {up} B is O(model) ({pb} B of "
                f"parameters): the scalar-only protocol is broken",
                up=up, param_bytes=pb))
        if not out:
            out.append(self.finding(
                program, f"collectives within budget: "
                f"all-gather {ag:.0f} B, other {others:.0f} B",
                severity="info", collectives=coll))
        return out


class MemoryCeilingRule(Rule):
    """Peak-live-bytes liveness estimate per program (regression gate via
    ``peak_bytes_budget``; the estimate always lands in the report so
    benchmarks/memory_footprint.py comparisons have a static counterpart)
    plus a VMEM-fit check: every pallas_call's block working set (kernel
    invars/outvars = inputs + outputs + scratch for one grid step) must
    fit the per-arch VMEM budget."""

    name = "memory-ceiling"
    description = "peak live bytes under budget; pallas blocks fit VMEM"
    needs = ("jaxpr",)

    def check(self, program, built, artifacts):
        out: List[Finding] = []
        jx = artifacts.jaxpr()
        peak = liveness_peak_bytes(jx)
        budget = built.meta.get("peak_bytes_budget")
        if budget is not None and peak > budget:
            out.append(self.finding(
                program, f"liveness peak estimate {peak} B exceeds budget "
                f"{budget} B", peak_bytes=peak, budget=budget))
        else:
            out.append(self.finding(
                program, f"liveness peak estimate {peak} B"
                + (f" (budget {budget} B)" if budget else ""),
                severity="info", peak_bytes=peak))
        vmem = built.meta.get("vmem_budget_bytes",
                              VMEM_BUDGETS[built.meta.get("arch", "tpu")])
        for rec in pallas_block_records(jx):
            if rec["block_bytes"] > vmem:
                out.append(self.finding(
                    program, f"pallas_call '{rec['name']}' block working "
                    f"set {rec['block_bytes']} B exceeds VMEM budget "
                    f"{vmem} B", name=rec["name"],
                    block_bytes=rec["block_bytes"], budget=vmem))
        return out


ALL_RULES = (DenseMaterializationRule(), DtypeDriftRule(), HostSyncRule(),
             RecompileHazardRule(), CommBudgetRule(), MemoryCeilingRule())


def rules_by_name(names=None):
    table = {r.name: r for r in ALL_RULES}
    if names is None:
        return list(ALL_RULES)
    missing = [n for n in names if n not in table]
    if missing:
        raise KeyError(f"unknown rule(s) {missing}; "
                       f"have {sorted(table)}")
    return [table[n] for n in names]
