"""Flash-decode attention Pallas kernel (one query token, blocked KV).

Online-softmax accumulation over KV blocks with VMEM scratch for the running
max / normalizer / value accumulator.  GQA layout: queries are grouped per
KV head ([B, KVH, G, dh]); the kernel grid is (B, KVH, S_blocks) with the
KV-block axis innermost (sequential accumulation).

Serving contract (the hot path of ``models/layers.decode_self_attention``):

* ``length`` is per-batch-row ([B] int32) — each continuous-batching slot
  attends to its own valid prefix of the shared fixed-capacity cache.
* ``softcap`` (gemma2-style logit capping) is applied pre-masking, matching
  ``layers.softcap``.
* ``S`` must be a block multiple; ``ops.flash_decode`` pads arbitrary cache
  lengths (padded keys sit at positions >= S >= length, always masked).

Validated in interpret=True mode against the pure-jnp oracle in ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(L_ref, q_ref, k_ref, v_ref, o_ref,
                        m_scr, l_scr, acc_scr, *, block_s: int, scale: float,
                        softcap: float):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # [G, dh]
    k = k_ref[0, :, 0].astype(jnp.float32)       # [Sblk, dh]
    v = v_ref[0, :, 0].astype(jnp.float32)       # [Sblk, dh]
    s = jnp.dot(q, k.T) * scale                  # [G, Sblk]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = i * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < L_ref[0], s, NEG_INF)

    m_prev = m_scr[...]                           # [G, 1]
    m_new = jnp.maximum(m_prev[:, 0], s.max(axis=-1))[:, None]
    alpha = jnp.exp(m_prev - m_new)               # [G, 1]
    p = jnp.exp(s - m_new)                        # [G, Sblk]
    l_scr[...] = l_scr[...] * alpha + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(p, v)
    m_scr[...] = m_new

    @pl.when(i == pl.num_programs(2) - 1)
    def _final():
        o_ref[0, 0] = (acc_scr[...] / l_scr[...]).astype(o_ref.dtype)


def decode_attention(q, k, v, length, *, block_s: int = 512,
                     softcap: float = 0.0, interpret: bool = True):
    """q: [B, KVH, G, dh]; k, v: [B, S, KVH, dh]; length: int or [B] int32
    (per-row valid KV prefix).

    Returns [B, KVH, G, dh] attention output (softmax over positions <
    length, with optional pre-mask tanh softcapping of the logits).
    """
    B, KVH, G, dh = q.shape
    S = k.shape[1]
    assert S % block_s == 0, (S, block_s)
    grid = (B, KVH, S // block_s)
    scale = dh ** -0.5
    L_arr = jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1), (B,))
    kernel = functools.partial(_decode_attn_kernel, block_s=block_s,
                               scale=scale, softcap=float(softcap))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i: (b,)),
            pl.BlockSpec((1, 1, G, dh), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda b, h, i: (b, i, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, i: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),   # running max m
            pltpu.VMEM((G, 1), jnp.float32),   # normalizer l
            pltpu.VMEM((G, dh), jnp.float32),  # value accumulator
        ],
        interpret=interpret,
    )(L_arr, q, k, v)
