"""Pallas TPU kernels (validated in interpret mode on CPU).

Each kernel module contains the pl.pallas_call + BlockSpec implementation;
``ops.py`` holds the jit'd public wrappers and ``ref.py`` the pure-jnp
oracles used by the sweep tests.
"""
from repro.kernels import ops, ref
