"""Fused sparse-ZO perturb / update Pallas TPU kernels.

The MEERKAT inner loop touches the flat parameter vector three times per
step when written naively (w+eps*z*m, w-eps*z*m, w-lr*g*z*m): three full HBM
round-trips.  These kernels fuse each phase into a single pass with
(8, 128)-tiled VMEM blocks:

* ``dual_perturb``: one read of (w, z, m) -> both perturbed copies.
* ``fused_update``: w' = w - lr * g * z * m  (g is a scalar operand).

Inputs are 2-D ``[R, 128]`` tiles of the flat parameter vector (the ops.py
wrapper pads/reshapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUB = 8
BLOCK_R = 256  # rows per block -> 256*128*4B = 128 KiB per f32 operand tile


def _dual_perturb_kernel(w_ref, z_ref, m_ref, eps_ref, plus_ref, minus_ref):
    w = w_ref[...]
    pert = (eps_ref[0] * z_ref[...] * m_ref[...]).astype(w.dtype)
    plus_ref[...] = w + pert
    minus_ref[...] = w - pert


def _dual_perturb_premasked_kernel(w_ref, z_ref, eps_ref, plus_ref,
                                   minus_ref):
    w = w_ref[...]
    pert = (eps_ref[0] * z_ref[...]).astype(w.dtype)
    plus_ref[...] = w + pert
    minus_ref[...] = w - pert


def dual_perturb(w, z, m, eps, *, block_r: int = BLOCK_R,
                 interpret: bool = True):
    """w, z, m: [R, 128] -> (w + eps*z*m, w - eps*z*m).

    ``m=None`` selects the pre-masked variant: z is already zero off the
    sparse coordinates (the dispatch layer's ``expand`` scatters it that
    way), so the mask operand — a third full HBM stream — is dropped."""
    R, C = w.shape
    assert C == LANE and R % block_r == 0, (w.shape, block_r)
    grid = (R // block_r,)
    if interpret and grid == (1,):
        # single-block interpret (the _fit_block_r CPU choice): the
        # interpreter machinery around one full-array grid step is pure
        # overhead over the mathematically identical jnp body — apply the
        # kernel math directly.  Multi-block grids (pinned in
        # tests/test_kernels.py) still run the real pallas_call path.
        eps_f = jnp.asarray(eps, jnp.float32)
        pert = (eps_f * z if m is None else eps_f * z * m).astype(w.dtype)
        return w + pert, w - pert
    spec = pl.BlockSpec((block_r, LANE), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    eps_arr = jnp.full((1,), eps, jnp.float32)
    if m is None:
        return pl.pallas_call(
            _dual_perturb_premasked_kernel,
            grid=grid,
            in_specs=[spec, spec, scalar_spec],
            out_specs=[spec, spec],
            out_shape=[jax.ShapeDtypeStruct(w.shape, w.dtype)] * 2,
            interpret=interpret,
        )(w, z, eps_arr)
    return pl.pallas_call(
        _dual_perturb_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, scalar_spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(w.shape, w.dtype)] * 2,
        interpret=interpret,
    )(w, z, m, eps_arr)


def _fused_update_kernel(w_ref, z_ref, m_ref, s_ref, out_ref):
    out_ref[...] = w_ref[...] + (s_ref[0] * z_ref[...]
                                 * m_ref[...]).astype(w_ref.dtype)


def _fused_update_premasked_kernel(w_ref, z_ref, s_ref, out_ref):
    out_ref[...] = w_ref[...] + (s_ref[0] * z_ref[...]).astype(w_ref.dtype)


def fused_update(w, z, m, scale, *, block_r: int = BLOCK_R,
                 interpret: bool = True):
    """w' = w + scale * z * m   (scale = -lr * g for the MEERKAT update).

    ``m=None``: pre-masked z (see :func:`dual_perturb`)."""
    R, C = w.shape
    assert C == LANE and R % block_r == 0, (w.shape, block_r)
    grid = (R // block_r,)
    if interpret and grid == (1,):
        # single-block interpret fast path; see dual_perturb
        s_f = jnp.asarray(scale, jnp.float32)
        upd = (s_f * z if m is None else s_f * z * m).astype(w.dtype)
        return w + upd
    spec = pl.BlockSpec((block_r, LANE), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    s_arr = jnp.asarray(scale, jnp.float32).reshape(1)
    if m is None:
        return pl.pallas_call(
            _fused_update_premasked_kernel,
            grid=grid,
            in_specs=[spec, spec, scalar_spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
            interpret=interpret,
        )(w, z, s_arr)
    return pl.pallas_call(
        _fused_update_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, scalar_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=interpret,
    )(w, z, m, s_arr)
