"""Jit'd public wrappers around the Pallas kernels.

On this CPU container kernels always run in interpret mode; on a real TPU
set ``interpret=False`` (the default flips on TPU platforms automatically).
The flat-vector helpers pad/reshape 1-D inputs into the (R, 128) tile layout
the kernels expect.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention as \
    flash_attention_kernel
from repro.kernels.gradip_reduce import LANE, gradip_reduce
from repro.kernels.zo_update import BLOCK_R, SUB, dual_perturb, fused_update


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _fit_block_r(n: int, interpret: bool) -> int:
    """Row-block for a flat [n] vector.

    Compiled (TPU): BLOCK_R rows per block — 128 KiB f32 operand tiles that
    fit VMEM — unless the vector is smaller, in which case just enough
    (8, 128) sublane tiles to hold it (tiny spaces don't pad to 32K elems).
    Interpret (CPU tests/sims): one grid step covering the whole vector —
    the interpreter costs milliseconds *per grid step*, and there is no
    VMEM bound to respect, so blocking would only multiply that overhead."""
    r_needed = -(-n // LANE)
    r8 = -(-r_needed // SUB) * SUB
    return r8 if interpret else min(BLOCK_R, r8)


def _tile(v, block_r: int):
    """Pad a flat [N] vector to [R, 128] with R % block_r == 0."""
    n = v.shape[0]
    per = LANE * block_r
    pad = (-n) % per
    return jnp.pad(v, (0, pad)).reshape(-1, LANE), n


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def zo_dual_perturb_flat(w_flat, z_flat, m_flat, eps, *,
                         block_r: int | None = None,
                         interpret: bool | None = None):
    """Flat-vector fused dual perturbation: returns (w+, w-) of shape [N].

    ``m_flat=None`` means z is already zero off the sparse coordinates
    (pre-masked by the dispatch layer) — the mask stream is skipped."""
    interpret = _default_interpret() if interpret is None else interpret
    n = w_flat.shape[0]
    block_r = _fit_block_r(n, interpret) if block_r is None else block_r
    w2, _ = _tile(w_flat, block_r)
    z2, _ = _tile(z_flat, block_r)
    m2 = None if m_flat is None else _tile(m_flat, block_r)[0]
    p, m_ = dual_perturb(w2, z2, m2, eps, block_r=block_r,
                         interpret=interpret)
    return p.reshape(-1)[:n], m_.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def zo_fused_update_flat(w_flat, z_flat, m_flat, scale, *,
                         block_r: int | None = None,
                         interpret: bool | None = None):
    """``m_flat=None``: pre-masked z, see :func:`zo_dual_perturb_flat`."""
    interpret = _default_interpret() if interpret is None else interpret
    n = w_flat.shape[0]
    block_r = _fit_block_r(n, interpret) if block_r is None else block_r
    w2, _ = _tile(w_flat, block_r)
    z2, _ = _tile(z_flat, block_r)
    m2 = None if m_flat is None else _tile(m_flat, block_r)[0]
    out = fused_update(w2, z2, m2, scale, block_r=block_r,
                       interpret=interpret)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def gradip_flat(gp_flat, z_flat, g, *, block_r: int = 256,
                interpret: bool | None = None):
    """GradIP = g * <gp, z> over flat sparse-coordinate vectors."""
    interpret = _default_interpret() if interpret is None else interpret
    gp2, _ = _tile(gp_flat, block_r)
    z2, _ = _tile(z_flat, block_r)
    return gradip_reduce(gp2, z2, g, block_r=block_r, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_s", "softcap",
                                             "interpret"))
def flash_decode(q, k, v, length, *, block_s: int = 512, softcap: float = 0.0,
                 interpret: bool | None = None):
    """GQA flash-decode attention; see decode_attention.py for layout.

    ``length`` may be a scalar or per-row [B].  Cache lengths that are not a
    block multiple are zero-padded up to one (the pad positions sit at
    ``pos >= S >= length`` and are always masked), so model-shaped caches
    of any capacity route through the kernel."""
    interpret = _default_interpret() if interpret is None else interpret
    S = k.shape[1]
    bs = min(block_s, -(-S // SUB) * SUB)  # small caches: one sublane-tiled block
    pad = (-S) % bs
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return decode_attention(q, k, v, length, block_s=bs, softcap=softcap,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "causal",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, lengths=None, *, window: int = 0,
                    softcap: float = 0.0, causal: bool = True,
                    block_q: int | None = None, block_k: int | None = None,
                    interpret: bool | None = None):
    """GQA flash-attention (differentiable); see flash_attention.py for the
    kernels — ``jax.grad`` through this wrapper runs the recompute-based
    backward Pallas kernels.

    Model layout in, model layout out: q [B, S, H, hd]; k, v [B, S, KV, hd]
    -> [B, S, H, hd] (H = KV * G, head h in group h // G — the same order
    ``jnp.repeat(k, G, axis=2)`` produces in the dense route).

    ``block_q``/``block_k`` default to the measured winner in the
    ``kernels.autotune`` table for this (S, head_dim, G) on this platform
    (falling back to 128x128 when untuned); pass them explicitly to pin a
    launch grid.  The lookup happens at trace time, so the choice is baked
    into the jitted computation.

    ``lengths`` ([B] int32 or None) masks right-padded keys.  Sequence
    lengths that are not a block multiple are zero-padded up to one: padded
    keys sit at positions >= S >= lengths so they are always masked, and
    padded query rows are trimmed from the output."""
    interpret = _default_interpret() if interpret is None else interpret
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if block_q is None or block_k is None:
        from repro.kernels import autotune
        tuned = autotune.best_blocks(S, hd, G, op="fwd")
        block_q = block_q or (tuned[0] if tuned else 128)
        block_k = block_k or (tuned[1] if tuned else 128)
    # small sequences: one sublane-tiled block per axis (mirrors flash_decode)
    s8 = -(-S // SUB) * SUB
    bq = min(block_q, s8)
    bk = min(block_k, s8)
    per = bq * bk // math.gcd(bq, bk)  # lcm: the pad covers both block sizes
    pad = (-S) % per
    if pad:
        cfgpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, cfgpad)
        k = jnp.pad(k, cfgpad)
        v = jnp.pad(v, cfgpad)
    if lengths is None:
        lengths = jnp.full((B,), S, jnp.int32)
    else:
        lengths = jnp.minimum(
            jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                             (B,)), S)
    Sp = S + pad
    qg = q.reshape(B, Sp, KV, G, hd).transpose(0, 2, 1, 3, 4)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    out = flash_attention_kernel(qg, kg, vg, lengths, block_q=bq, block_k=bk,
                                 window=window, softcap=softcap,
                                 causal=causal, interpret=interpret)
    out = out.transpose(0, 2, 1, 3, 4).reshape(B, Sp, H, hd)
    return out[:, :S]


@functools.partial(jax.jit, static_argnames=("interpret",))
def mamba_scan_op(dt, B_in, C_in, x, A, *, interpret: bool | None = None):
    """Selective-scan kernel wrapper; picks kernel blocks fitting the shape.

    dt, x: [B,S,E]; B_in, C_in: [B,S,N]; A: [E,N] -> (y, h_last)."""
    from repro.kernels.mamba_scan import mamba_scan
    interpret = _default_interpret() if interpret is None else interpret
    B, S, E = dt.shape

    def fit(n, target):
        b = min(target, n)
        while n % b:
            b -= 1
        return b

    return mamba_scan(dt, B_in, C_in, x, A, e_block=fit(E, 128),
                      s_block=fit(S, 256), interpret=interpret)
