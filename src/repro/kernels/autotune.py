"""Measured block-size autotuner for the flash-attention kernels.

``BENCH_attn.json`` showed the Pallas forward *trailing* the online-softmax
jnp route at every sequence length on this host with the fixed (128, 128)
block heuristic.  Rather than guess, this module measures: for a given
(op, S, head_dim, G) problem it times the Pallas kernel over a candidate
(block_q, block_k) grid *and* the online jnp route, persists the winner to
an on-disk JSON table, and serves lookups to

* ``ops.flash_attention`` — which blocks to launch with when the caller
  does not pin them, and
* ``models.layers.resolve_attn_backend`` — whether ``"auto"`` should route
  to pallas at all for that key (``fastest_route``), including falling back
  to online where pallas genuinely loses.

Table location: ``$REPRO_AUTOTUNE_DIR`` or ``<repo>/runs/autotune/``, file
``attn_table.json``.  Keys are ``{op}|{platform}|S{S}|hd{head_dim}|G{G}``
with ``op`` in {fwd, grad} and ``platform`` either ``interpret`` (off-TPU
— the kernels run in interpret mode, measurements do not transfer to
hardware) or the accelerator's device kind, so a table tuned on one host
never misroutes another.  Entry schema (DESIGN.md §perf)::

    {"route": "pallas" | "online",      # measured-fastest route
     "block_q": 128, "block_k": 128,    # best pallas blocks
     "best_pallas_ms": 1.9, "online_ms": 2.4,
     "pallas_ms": {"64x64": 2.5, ...},  # full candidate timings
     "reps": 3, "batch": 1, "kv_heads": 1}

Cached entries are authoritative: ``ensure`` never re-measures an existing
key unless ``force=True``, so two runs over the same shapes produce
identical picks (the CI determinism gate, ``--require-cached``).

CLI::

    PYTHONPATH=src python -m repro.kernels.autotune \
        --s-list 256,1024,2048 --head-dim 16 --g 4 --ops fwd,grad
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time
import types
from typing import Dict, Optional, Tuple

TABLE_NAME = "attn_table.json"
_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                          "..", "..", ".."))
DEFAULT_TABLE_DIR = os.path.join(_REPO_ROOT, "runs", "autotune")
OPS = ("fwd", "grad")
# candidate (block_q, block_k) launch grids; clamped to the padded S and
# deduped per problem before timing
CANDIDATES = ((64, 64), (64, 128), (128, 64), (128, 128),
              (128, 256), (256, 128), (256, 256))


def platform_key() -> str:
    """Measurement-validity domain for table keys: ``interpret`` off-TPU
    (kernels run in the Pallas interpreter), else the device kind."""
    import jax

    from repro.kernels.ops import _default_interpret
    if _default_interpret():
        return "interpret"
    return jax.devices()[0].device_kind.replace(" ", "_").lower()


def table_dir(dirname: Optional[str] = None) -> str:
    return (dirname or os.environ.get("REPRO_AUTOTUNE_DIR")
            or DEFAULT_TABLE_DIR)


def table_path(dirname: Optional[str] = None) -> str:
    return os.path.join(table_dir(dirname), TABLE_NAME)


_CACHE: Dict[str, dict] = {}


def clear_cache() -> None:
    """Drop the in-process table cache (tests / after external writes)."""
    _CACHE.clear()


def load_table(dirname: Optional[str] = None) -> dict:
    path = table_path(dirname)
    if path not in _CACHE:
        tab = {}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    tab = json.load(f)
            except (json.JSONDecodeError, OSError):
                tab = {}
        _CACHE[path] = tab
    return _CACHE[path]


def _save(tab: dict, dirname: Optional[str]) -> str:
    os.makedirs(table_dir(dirname), exist_ok=True)
    path = table_path(dirname)
    with open(path, "w") as f:
        json.dump(tab, f, indent=1, sort_keys=True)
    _CACHE[path] = tab
    return path


def key_for(op: str, S: int, head_dim: int, G: int,
            platform: Optional[str] = None) -> str:
    assert op in OPS, op
    return f"{op}|{platform or platform_key()}|S{S}|hd{head_dim}|G{G}"


def lookup(op: str, S: int, head_dim: int, G: int,
           dirname: Optional[str] = None) -> Optional[dict]:
    return load_table(dirname).get(key_for(op, S, head_dim, G))


def best_blocks(S: int, head_dim: int, G: int, op: str = "fwd",
                dirname: Optional[str] = None) -> Optional[Tuple[int, int]]:
    """Measured-best (block_q, block_k) for the key, or None if untuned.

    Falls back to the other op's entry — block preferences transfer far
    better across fwd/grad than across (S, head_dim) keys."""
    for o in (op,) + tuple(x for x in OPS if x != op):
        e = lookup(o, S, head_dim, G, dirname)
        if e and "block_q" in e:
            return int(e["block_q"]), int(e["block_k"])
    return None


def fastest_route(S: int, head_dim: int, G: int, op: str = "fwd",
                  dirname: Optional[str] = None) -> Optional[str]:
    """Measured-fastest route ('pallas' | 'online') for the exact key, or
    None when the key was never tuned on this platform."""
    e = lookup(op, S, head_dim, G, dirname)
    return e.get("route") if e else None


# ----------------------------------------------------------- measuring ----
def _time_best(fn, args, reps: int) -> float:
    import jax
    jax.block_until_ready(fn(*args))  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3  # ms


def measure(op: str, S: int, head_dim: int, G: int, *, kv_heads: int = 1,
            batch: int = 1, reps: int = 3, candidates=None,
            seed: int = 0) -> dict:
    """Time pallas over the candidate grid and the online route; return a
    table entry (does not persist — see :func:`ensure`)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops as K
    from repro.models import layers as L

    assert op in OPS, op
    cfg = types.SimpleNamespace(attn_softcap=0.0)
    B, KV, H = batch, kv_heads, kv_heads * G
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, S, H, head_dim)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, head_dim)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, head_dim)), jnp.float32)

    def pallas_fwd(bq, bk):
        return jax.jit(functools.partial(K.flash_attention,
                                         block_q=bq, block_k=bk))

    def online_fwd(q, k, v):
        return L.online_gqa_attention(q, k, v, cfg)

    if op == "fwd":
        routes = {"online": jax.jit(online_fwd)}

        def cand_fn(bq, bk):
            return pallas_fwd(bq, bk)
    else:
        def grad_of(route):
            return jax.jit(jax.grad(
                lambda q, k, v: route(q, k, v).astype(jnp.float32).sum(),
                argnums=(0, 1, 2)))
        routes = {"online": grad_of(online_fwd)}

        def cand_fn(bq, bk):
            return grad_of(lambda q, k, v: K.flash_attention(
                q, k, v, block_q=bq, block_k=bk))

    cands, seen = [], set()
    for bq, bk in (candidates or CANDIDATES):
        bq, bk = min(bq, S), min(bk, S)
        # a candidate whose score block [block_q*G, block_k] reaches
        # [S, S] is a degenerate single-tile launch — it reintroduces
        # the dense-sized buffer the blockwise routes are proven free of
        # (the no-[S,S] jaxpr walk), so it is never eligible to win
        if bq * G >= S and bk >= S:
            continue
        if (bq, bk) not in seen:
            seen.add((bq, bk))
            cands.append((bq, bk))
    if not cands:
        # every candidate degenerate at this S (small S, large G):
        # halve block_k on the smallest candidate to keep the KV axis
        # tiled and the invariant intact
        bq, bk = min((candidates or CANDIDATES))
        cands = [(min(bq, S), max(8, min(bk, S) // 2))]

    pallas_ms = {f"{bq}x{bk}": _time_best(cand_fn(bq, bk), (q, k, v), reps)
                 for bq, bk in cands}
    online_ms = _time_best(routes["online"], (q, k, v), reps)
    best_key = min(pallas_ms, key=pallas_ms.get)
    bq, bk = (int(x) for x in best_key.split("x"))
    best = pallas_ms[best_key]
    return dict(route="pallas" if best < online_ms else "online",
                block_q=bq, block_k=bk,
                best_pallas_ms=round(best, 4),
                online_ms=round(online_ms, 4),
                pallas_ms={k: round(v, 4) for k, v in pallas_ms.items()},
                reps=reps, batch=batch, kv_heads=kv_heads)


def ensure(op: str, S: int, head_dim: int, G: int, *, kv_heads: int = 1,
           batch: int = 1, reps: int = 3, candidates=None, force: bool = False,
           dirname: Optional[str] = None) -> Tuple[dict, bool]:
    """Return (entry, measured): the cached entry if present (measured =
    False — cached picks are authoritative and deterministic), else
    measure, persist, and return it (measured = True)."""
    key = key_for(op, S, head_dim, G)
    tab = load_table(dirname)
    if key in tab and not force:
        return tab[key], False
    entry = measure(op, S, head_dim, G, kv_heads=kv_heads, batch=batch,
                    reps=reps, candidates=candidates)
    tab = dict(tab)
    tab[key] = entry
    _save(tab, dirname)
    return entry, True


# ------------------------------------------------------------------ CLI ----
def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Tune flash-attention (block_q, block_k) per "
                    "(op, S, head_dim, G) and persist winners to "
                    "runs/autotune/attn_table.json")
    ap.add_argument("--s-list", default="256,1024,2048",
                    help="comma-separated sequence lengths to tune")
    ap.add_argument("--head-dim", type=int, default=16,
                    help="attention head dim (TINY default)")
    ap.add_argument("--g", type=int, default=4,
                    help="query heads per KV head (GQA group size)")
    ap.add_argument("--kv-heads", type=int, default=1,
                    help="KV heads in the measurement problem")
    ap.add_argument("--batch", type=int, default=1,
                    help="batch rows in the measurement problem")
    ap.add_argument("--reps", type=int, default=3,
                    help="best-of-N timing repetitions")
    ap.add_argument("--ops", default="fwd,grad",
                    help="which ops to tune: fwd, grad or both")
    ap.add_argument("--table-dir", default=None,
                    help="table directory (default: $REPRO_AUTOTUNE_DIR "
                         "or runs/autotune)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: S=256 only, reps=1, 2 candidates")
    ap.add_argument("--force", action="store_true",
                    help="re-measure keys already in the table")
    ap.add_argument("--require-cached", action="store_true",
                    help="exit 1 if any key had to be measured (CI "
                         "determinism gate: a second run must be all-cached)")
    ap.add_argument("--list", action="store_true",
                    help="print the current table and exit")
    a = ap.parse_args(argv)

    if a.list:
        tab = load_table(a.table_dir)
        print(json.dumps(tab, indent=1, sort_keys=True))
        print(f"{len(tab)} entries at {table_path(a.table_dir)}")
        return 0

    s_list = [int(s) for s in a.s_list.split(",") if s]
    cands = None
    reps = a.reps
    if a.smoke:
        s_list, reps, cands = [256], 1, ((64, 64), (128, 128))
    ops = [o.strip() for o in a.ops.split(",") if o.strip()]
    measured_any = False
    for op in ops:
        for S in s_list:
            entry, measured = ensure(
                op, S, a.head_dim, a.g, kv_heads=a.kv_heads, batch=a.batch,
                reps=reps, candidates=cands, force=a.force,
                dirname=a.table_dir)
            measured_any |= measured
            tag = "measured" if measured else "cached"
            print(f"  {key_for(op, S, a.head_dim, a.g):40s} -> "
                  f"{entry['route']:6s} bq={entry['block_q']} "
                  f"bk={entry['block_k']} "
                  f"(pallas {entry['best_pallas_ms']:.2f}ms vs online "
                  f"{entry['online_ms']:.2f}ms) [{tag}]")
    print(f"table: {table_path(a.table_dir)}")
    if a.require_cached and measured_any:
        print("FAIL: --require-cached but keys were (re)measured")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
