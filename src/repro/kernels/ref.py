"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dual_perturb_ref(w, z, m, eps):
    pert = (eps * z * m).astype(w.dtype)
    return w + pert, w - pert


def fused_update_ref(w, z, m, scale):
    return w + (scale * z * m).astype(w.dtype)


def gradip_reduce_ref(gp, z, g):
    return jnp.asarray(g, jnp.float32) * jnp.sum(
        gp.astype(jnp.float32) * z.astype(jnp.float32))


def mamba_scan_ref(dt, B_in, C_in, x, A):
    """Serial selective-scan oracle.  dt, x: [B,S,E]; B_in, C_in: [B,S,N];
    A: [E,N] -> (y [B,S,E], h_last [B,E,N])."""
    B, S, E = dt.shape
    N = B_in.shape[-1]

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp
        decay = jnp.exp(dt_t[..., None] * A)              # [B,E,N]
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("ben,bn->be", h, c_t)
        return h, y

    h0 = jnp.zeros((B, E, N), jnp.float32)
    xs = (dt.swapaxes(0, 1), B_in.swapaxes(0, 1), C_in.swapaxes(0, 1),
          x.swapaxes(0, 1))
    h_last, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h_last


def decode_attention_ref(q, k, v, length, softcap: float = 0.0):
    """q: [B,KVH,G,dh]; k,v: [B,S,KVH,dh]; softmax over positions < length.

    ``length`` is a scalar or per-row [B] (continuous-batching slots)."""
    B, KVH, G, dh = q.shape
    S = k.shape[1]
    scale = dh ** -0.5
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    L = jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1), (B,))
    mask = jnp.arange(S)[None, None, None, :] < L[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
