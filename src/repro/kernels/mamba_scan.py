"""Selective-SSM (Mamba-1) scan Pallas TPU kernel.

The jnp chunked associative scan materializes the per-element decay/state
pairs [B, L, E, N] in HBM at every tree level — on the jamba train_4k
dry-run this is ~3 TB of per-device traffic per step (§Perf pair 3).  The
TPU-native structure is the same as the CUDA hardware-aware scan: stream
(dt, B, C, x) through VMEM in (S_block, E_block) tiles, keep the running
state h [E_block, N] in a VMEM scratch across the sequence grid axis, and
write only y.  HBM traffic becomes one read of the inputs + one write of
the output: O(S*E) instead of O(S*E*N*log L).

Layout: grid = (B, E/E_block, S/S_block); the S axis is the innermost
(fastest) grid dim, executed sequentially per (b, e) program on TPU, so
the VMEM scratch state carries across S blocks.  Inside a block the
recurrence is a fori_loop over S_block steps of [E_block, N] FMAs —
entirely in VMEM/VREGs.

h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t     (diag A, outer B)
y_t = <h_t, C_t>                                        (D*x added outside)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

E_BLOCK = 128
S_BLOCK = 256


def _kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, y_ref, h_out_ref, h_scratch):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    a = a_ref[...]                       # [E_blk, N]
    dt = dt_ref[0]                       # [S_blk, E_blk]
    bb = b_ref[0]                        # [S_blk, N]
    cc = c_ref[0]                        # [S_blk, N]
    xx = x_ref[0]                        # [S_blk, E_blk]

    def step(t, h):
        dt_t = dt[t][:, None]            # [E_blk, 1]
        decay = jnp.exp(dt_t * a)        # [E_blk, N]
        db = (dt_t * xx[t][:, None]) * bb[t][None, :]
        h = decay * h + db
        y_ref[0, t, :] = jnp.sum(h * cc[t][None, :], axis=-1
                                 ).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, dt.shape[0], step, h_scratch[...])
    h_scratch[...] = h

    @pl.when(s_idx == n_s - 1)
    def _emit():
        h_out_ref[0] = h_scratch[...]


def mamba_scan(dt, B_in, C_in, x, A, *, e_block: int = E_BLOCK,
               s_block: int = S_BLOCK, interpret: bool = True):
    """dt, x: [B,S,E] (f32, dt post-softplus); B_in, C_in: [B,S,N]; A: [E,N].

    Returns (y [B,S,E] f32, h_last [B,E,N] f32)."""
    B, S, E = dt.shape
    N = B_in.shape[-1]
    assert E % e_block == 0 and S % s_block == 0, (dt.shape, e_block, s_block)
    grid = (B, E // e_block, S // s_block)
    se_spec = pl.BlockSpec((1, s_block, e_block), lambda b, e, s: (b, s, e))
    sn_spec = pl.BlockSpec((1, s_block, N), lambda b, e, s: (b, s, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[se_spec, sn_spec, sn_spec, se_spec,
                  pl.BlockSpec((e_block, N), lambda b, e, s: (e, 0))],
        out_specs=[se_spec,
                   pl.BlockSpec((1, e_block, N), lambda b, e, s: (b, e, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, S, E), jnp.float32),
                   jax.ShapeDtypeStruct((B, E, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((e_block, N), jnp.float32)],
        interpret=interpret,
    )(dt, B_in, C_in, x, A)
