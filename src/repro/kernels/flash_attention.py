"""Flash-attention forward Pallas kernel (blockwise online softmax).

The training/prefill counterpart of ``decode_attention.py``: every MEERKAT
step pays 2*n_dirs full forwards (Eq. 1), so the attention forward is the
step-time and peak-memory bound at realistic sequence lengths.  This kernel
streams K/V block by block with online-softmax accumulation in VMEM scratch
and never materializes an [S, S] score matrix.

GQA layout: queries are grouped per KV head ([B, KVH, S, G, dh] — no KV
repeat; the G query heads of a group share one K/V stream).  The grid is
(B, KVH, S/block_q, S/block_k) with the KV-block axis innermost (sequential
accumulation into the running max / normalizer / value scratch, exactly the
flash-decode recurrence).  Inside a block the G axis is folded into the
query rows so the score matmul is a single [block_q*G, dh] x [dh, block_k]
MXU contraction.

Forward-attention contract (the hot path of ``models/layers`` routed via
``resolve_attn_backend``):

* causal masking, optionally banded to a sliding ``window`` (gemma2-style
  local layers);
* ``softcap`` tanh logit capping applied pre-masking (``layers.softcap``);
* ``lengths`` is per-batch-row ([B] int32) key validity for right-padded
  prefill — keys at positions >= lengths[b] are masked for every query, so
  a padded batched prefill matches prefilling each row alone;
* f32 accumulation regardless of operand dtype;
* KV blocks that are entirely masked (future of the causal frontier, behind
  the sliding-window band, or past the row's length) skip their compute
  under ``pl.when``;
* ``S`` must be a block multiple; ``ops.flash_attention`` pads arbitrary
  lengths (padded keys sit at positions >= S >= lengths, always masked, and
  padded query rows are trimmed).

Validated in interpret=True mode against the dense / online jnp routes in
``models/layers`` (tests/test_attn_backends.py).  The kernel defines no
VJP: ``jax.grad`` callers resolve to the differentiable online/dense routes
(see ``layers.differentiable_attn``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_attn_kernel(L_ref, q_ref, k_ref, v_ref, o_ref,
                       m_scr, l_scr, acc_scr, *, block_q: int, block_k: int,
                       G: int, scale: float, softcap: float, window: int,
                       causal: bool):
    i = pl.program_id(2)   # query block
    j = pl.program_id(3)   # KV block (innermost: sequential accumulation)
    q0 = i * block_q
    k0 = j * block_k

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level pruning: a KV block with no live (query, key) pair
    # contributes nothing to the running stats — skip its matmuls.
    needed = k0 < L_ref[0]
    if causal:
        needed &= k0 <= q0 + block_q - 1
    if window:
        needed &= (k0 + block_k - 1) > (q0 - window)

    @pl.when(needed)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32)      # [block_q, G, dh]
        dh = q.shape[-1]
        q2 = q.reshape(block_q * G, dh)          # row r <-> query q0 + r//G
        k = k_ref[0, 0].astype(jnp.float32)      # [block_k, dh]
        v = v_ref[0, 0].astype(jnp.float32)      # [block_k, dh]
        s = jnp.dot(q2, k.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        rows = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        cols = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = cols < L_ref[0]
        if causal:
            valid &= cols <= rows
        if window:
            valid &= cols > rows - window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]                       # [block_q*G, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # mask p explicitly: on a fully-masked row m_new is still NEG_INF
        # and exp(s - m_new) would be 1, not 0
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = out.reshape(block_q, G, -1).astype(o_ref.dtype)


def flash_attention(q, k, v, lengths, *, block_q: int = 128,
                    block_k: int = 128, window: int = 0, softcap: float = 0.0,
                    causal: bool = True, interpret: bool = True):
    """q: [B, KVH, S, G, dh]; k, v: [B, KVH, S, dh]; lengths: int or [B]
    int32 (per-row valid KV prefix).

    Returns [B, KVH, S, G, dh] attention output: for query position t,
    softmax over key positions p with p < lengths[b], p <= t (causal) and
    t - window < p (when window > 0), with optional pre-mask tanh
    softcapping of the logits and f32 accumulation.
    """
    B, KVH, S, G, dh = q.shape
    assert k.shape == (B, KVH, S, dh), (q.shape, k.shape)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    grid = (B, KVH, S // block_q, S // block_k)
    scale = dh ** -0.5
    L_arr = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                             (B,))
    kernel = functools.partial(
        _flash_attn_kernel, block_q=block_q, block_k=block_k, G=G,
        scale=scale, softcap=float(softcap), window=int(window),
        causal=bool(causal))
    kv_spec = pl.BlockSpec((1, 1, block_k, dh), lambda b, h, i, j: (b, h, j, 0))
    q_spec = pl.BlockSpec((1, 1, block_q, G, dh),
                          lambda b, h, i, j: (b, h, i, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i, j: (b,)),
            q_spec,
            kv_spec,
            kv_spec,
        ],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, S, G, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * G, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q * G, 1), jnp.float32),   # normalizer l
            pltpu.VMEM((block_q * G, dh), jnp.float32),  # value accumulator
        ],
        interpret=interpret,
    )(L_arr, q, k, v)
