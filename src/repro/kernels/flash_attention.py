"""Flash-attention Pallas kernels: blockwise online-softmax forward and a
recompute-based backward (``jax.custom_vjp``).

The training/prefill counterpart of ``decode_attention.py``: every MEERKAT
step pays 2*n_dirs full forwards (Eq. 1), so the attention forward is the
step-time and peak-memory bound at realistic sequence lengths.  The forward
streams K/V block by block with online-softmax accumulation in VMEM scratch
and never materializes an [S, S] score matrix.

GQA layout: queries are grouped per KV head ([B, KVH, S, G, dh] — no KV
repeat; the G query heads of a group share one K/V stream).  The forward
grid is (B, KVH, S/block_q, S/block_k) with the KV-block axis innermost
(sequential accumulation into the running max / normalizer / value scratch,
exactly the flash-decode recurrence).  Inside a block the G axis is folded
into the query rows so the score matmul is a single [block_q*G, dh] x
[dh, block_k] MXU contraction.

Forward-attention contract (the hot path of ``models/layers`` routed via
``resolve_attn_backend``):

* causal masking, optionally banded to a sliding ``window`` (gemma2-style
  local layers);
* ``softcap`` tanh logit capping applied pre-masking (``layers.softcap``);
* ``lengths`` is per-batch-row ([B] int32) key validity for right-padded
  prefill — keys at positions >= lengths[b] are masked for every query, so
  a padded batched prefill matches prefilling each row alone;
* f32 accumulation regardless of operand dtype;
* KV blocks that are entirely masked (future of the causal frontier, behind
  the sliding-window band, or past the row's length) skip their compute
  under ``pl.when``;
* ``S`` must be a block multiple; ``ops.flash_attention`` pads arbitrary
  lengths (padded keys sit at positions >= S >= lengths, always masked, and
  padded query rows are trimmed).

Backward (the VJP): the forward saves only its output O and the per-row
logsumexp ``lse = m + log(l)`` — O(S*dh + S) residuals instead of the
O(S^2) score matrices a naive differentiable route stacks.  The backward
*recomputes* the score blocks from (q, k, lse) and accumulates

    p  = exp(s - lse)            (the already-normalized probabilities)
    dV = p^T @ dO
    dp = dO @ V^T
    ds = p * (dp - delta),  delta = rowsum(dO * O)
    dQ = ds @ K * scale,    dK = ds^T @ Q * scale

over two kernels: a dQ pass (grid (B, KVH, nq, nk), KV innermost,
accumulating the query block's dQ in VMEM scratch) and a dK/dV pass (grid
(B, KVH, nk, nq), query innermost, accumulating the KV block's dK/dV).
Both reuse the forward's block-pruning predicate, so fully-masked blocks
cost nothing in the backward either.  The tanh softcap backward folds in
as ``ds_raw = ds * (1 - (s_cap/cap)^2)``.  ``lengths`` is integer-typed
and gets a ``float0`` cotangent.

Validated in interpret=True mode against the dense / online jnp routes in
``models/layers`` (tests/test_attn_backends.py, tests/test_attn_vjp.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


class Static(NamedTuple):
    """Hashable non-diff config threaded through the custom_vjp."""
    block_q: int
    block_k: int
    window: int
    softcap: float
    causal: bool
    interpret: bool


def _block_needed(L0, q0, k0, *, block_q, block_k, window, causal):
    """Forward/backward shared block-pruning predicate: does KV block at
    ``k0`` hold any live (query, key) pair for the query block at ``q0``?"""
    needed = k0 < L0
    if causal:
        needed &= k0 <= q0 + block_q - 1
    if window:
        needed &= (k0 + block_k - 1) > (q0 - window)
    return needed


def _valid_mask(L0, q0, k0, shape, *, G, window, causal):
    """[block_q*G, block_k] bool validity; row r <-> query q0 + r // G."""
    rows = q0 + jax.lax.broadcasted_iota(jnp.int32, shape, 0) // G
    cols = k0 + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    valid = cols < L0
    if causal:
        valid &= cols <= rows
    if window:
        valid &= cols > rows - window
    return valid


# ------------------------------------------------------------- forward ----
def _flash_attn_kernel(L_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                       m_scr, l_scr, acc_scr, *, block_q: int, block_k: int,
                       G: int, scale: float, softcap: float, window: int,
                       causal: bool):
    i = pl.program_id(2)   # query block
    j = pl.program_id(3)   # KV block (innermost: sequential accumulation)
    q0 = i * block_q
    k0 = j * block_k

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level pruning: a KV block with no live (query, key) pair
    # contributes nothing to the running stats — skip its matmuls.
    needed = _block_needed(L_ref[0], q0, k0, block_q=block_q,
                           block_k=block_k, window=window, causal=causal)

    @pl.when(needed)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32)      # [block_q, G, dh]
        dh = q.shape[-1]
        q2 = q.reshape(block_q * G, dh)          # row r <-> query q0 + r//G
        k = k_ref[0, 0].astype(jnp.float32)      # [block_k, dh]
        v = v_ref[0, 0].astype(jnp.float32)      # [block_k, dh]
        s = jnp.dot(q2, k.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        valid = _valid_mask(L_ref[0], q0, k0, s.shape, G=G, window=window,
                            causal=causal)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]                       # [block_q*G, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # mask p explicitly: on a fully-masked row m_new is still NEG_INF
        # and exp(s - m_new) would be 1, not 0
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _finalize():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = out.reshape(block_q, G, -1).astype(o_ref.dtype)
        # per-row logsumexp residual: exp(s - lse) is the final normalized
        # probability, the only softmax state the backward needs
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        lse_ref[0, 0] = lse.reshape(block_q, G)


def _fwd_call(st: Static, q, k, v, L_arr):
    """pallas_call for the forward; returns (out, lse [B,KVH,S,G] f32)."""
    B, KVH, S, G, dh = q.shape
    grid = (B, KVH, S // st.block_q, S // st.block_k)
    kernel = functools.partial(
        _flash_attn_kernel, block_q=st.block_q, block_k=st.block_k, G=G,
        scale=dh ** -0.5, softcap=float(st.softcap), window=int(st.window),
        causal=bool(st.causal))
    kv_spec = pl.BlockSpec((1, 1, st.block_k, dh),
                           lambda b, h, i, j: (b, h, j, 0))
    q_spec = pl.BlockSpec((1, 1, st.block_q, G, dh),
                          lambda b, h, i, j: (b, h, i, 0, 0))
    lse_spec = pl.BlockSpec((1, 1, st.block_q, G),
                            lambda b, h, i, j: (b, h, i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda b, h, i, j: (b,)),
                  q_spec, kv_spec, kv_spec],
        out_specs=[q_spec, lse_spec],
        out_shape=[jax.ShapeDtypeStruct((B, KVH, S, G, dh), q.dtype),
                   jax.ShapeDtypeStruct((B, KVH, S, G), jnp.float32)],
        scratch_shapes=[
            pltpu.VMEM((st.block_q * G, 1), jnp.float32),   # running max m
            pltpu.VMEM((st.block_q * G, 1), jnp.float32),   # normalizer l
            pltpu.VMEM((st.block_q * G, dh), jnp.float32),  # value acc
        ],
        interpret=st.interpret,
    )(L_arr, q, k, v)


# ------------------------------------------------------------ backward ----
def _recompute_p_ds(L0, q_ref, k_ref, v_ref, lse_ref, delta_ref, do_ref,
                    q0, k0, *, block_q, block_k, G, scale, softcap, window,
                    causal):
    """Shared backward block math: recompute p and ds for one
    (query-block, KV-block) tile.  Returns (p, ds, q2, k, do2), every
    operand f32 with the G axis folded into rows."""
    q = q_ref[0, 0].astype(jnp.float32)          # [block_q, G, dh]
    dh = q.shape[-1]
    q2 = q.reshape(block_q * G, dh)
    k = k_ref[0, 0].astype(jnp.float32)          # [block_k, dh]
    v = v_ref[0, 0].astype(jnp.float32)          # [block_k, dh]
    do = do_ref[0, 0].astype(jnp.float32).reshape(block_q * G, dh)
    s = jnp.dot(q2, k.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = _valid_mask(L0, q0, k0, s.shape, G=G, window=window,
                        causal=causal)
    lse = lse_ref[0, 0].reshape(block_q * G, 1)  # f32
    # explicit zero where invalid: on fully-masked rows lse is ~NEG_INF and
    # exp(s - lse) would overflow / evaluate to 1 at masked s, not 0
    p = jnp.where(valid, jnp.exp(s - lse), 0.0)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    delta = delta_ref[0, 0].reshape(block_q * G, 1)
    ds = p * (dp - delta)
    if softcap:
        # s here is the *capped* logit: d tanh-cap/d raw = 1 - (s/cap)^2
        ds = ds * (1.0 - jnp.square(s / softcap))
    return p, ds, q2, k, do


def _flash_attn_bwd_dq_kernel(L_ref, q_ref, k_ref, v_ref, lse_ref, delta_ref,
                              do_ref, dq_ref, dq_scr, *, block_q: int,
                              block_k: int, G: int, scale: float,
                              softcap: float, window: int, causal: bool):
    i = pl.program_id(2)   # query block
    j = pl.program_id(3)   # KV block (innermost: accumulate dq)
    q0 = i * block_q
    k0 = j * block_k

    @pl.when(j == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    needed = _block_needed(L_ref[0], q0, k0, block_q=block_q,
                           block_k=block_k, window=window, causal=causal)

    @pl.when(needed)
    def _accumulate():
        _, ds, _, k, _ = _recompute_p_ds(
            L_ref[0], q_ref, k_ref, v_ref, lse_ref, delta_ref, do_ref,
            q0, k0, block_q=block_q, block_k=block_k, G=G, scale=scale,
            softcap=softcap, window=window, causal=causal)
        dq_scr[...] += jnp.dot(ds, k,
                               preferred_element_type=jnp.float32) * scale

    @pl.when(j == pl.num_programs(3) - 1)
    def _finalize():
        dq_ref[0, 0] = dq_scr[...].reshape(block_q, G, -1)


def _flash_attn_bwd_dkv_kernel(L_ref, q_ref, k_ref, v_ref, lse_ref,
                               delta_ref, do_ref, dk_ref, dv_ref, dk_scr,
                               dv_scr, *, block_q: int, block_k: int, G: int,
                               scale: float, softcap: float, window: int,
                               causal: bool):
    j = pl.program_id(2)   # KV block
    i = pl.program_id(3)   # query block (innermost: accumulate dk/dv)
    q0 = i * block_q
    k0 = j * block_k

    @pl.when(i == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    needed = _block_needed(L_ref[0], q0, k0, block_q=block_q,
                           block_k=block_k, window=window, causal=causal)

    @pl.when(needed)
    def _accumulate():
        p, ds, q2, _, do = _recompute_p_ds(
            L_ref[0], q_ref, k_ref, v_ref, lse_ref, delta_ref, do_ref,
            q0, k0, block_q=block_q, block_k=block_k, G=G, scale=scale,
            softcap=softcap, window=window, causal=causal)
        dv_scr[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dk_scr[...] += jnp.dot(ds.T, q2,
                               preferred_element_type=jnp.float32) * scale

    @pl.when(i == pl.num_programs(3) - 1)
    def _finalize():
        dk_ref[0, 0] = dk_scr[...]
        dv_ref[0, 0] = dv_scr[...]


def _bwd_dq_call(st: Static, q, k, v, L_arr, lse, delta, do):
    B, KVH, S, G, dh = q.shape
    grid = (B, KVH, S // st.block_q, S // st.block_k)
    kernel = functools.partial(
        _flash_attn_bwd_dq_kernel, block_q=st.block_q, block_k=st.block_k,
        G=G, scale=dh ** -0.5, softcap=float(st.softcap),
        window=int(st.window), causal=bool(st.causal))
    kv_spec = pl.BlockSpec((1, 1, st.block_k, dh),
                           lambda b, h, i, j: (b, h, j, 0))
    q_spec = pl.BlockSpec((1, 1, st.block_q, G, dh),
                          lambda b, h, i, j: (b, h, i, 0, 0))
    row_spec = pl.BlockSpec((1, 1, st.block_q, G),
                            lambda b, h, i, j: (b, h, i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda b, h, i, j: (b,)),
                  q_spec, kv_spec, kv_spec, row_spec, row_spec, q_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, S, G, dh), jnp.float32),
        scratch_shapes=[pltpu.VMEM((st.block_q * G, dh), jnp.float32)],
        interpret=st.interpret,
    )(L_arr, q, k, v, lse, delta, do)


def _bwd_dkv_call(st: Static, q, k, v, L_arr, lse, delta, do):
    B, KVH, S, G, dh = q.shape
    # query axis innermost: each KV block accumulates over all query blocks
    grid = (B, KVH, S // st.block_k, S // st.block_q)
    kernel = functools.partial(
        _flash_attn_bwd_dkv_kernel, block_q=st.block_q, block_k=st.block_k,
        G=G, scale=dh ** -0.5, softcap=float(st.softcap),
        window=int(st.window), causal=bool(st.causal))
    kv_spec = pl.BlockSpec((1, 1, st.block_k, dh),
                           lambda b, h, j, i: (b, h, j, 0))
    q_spec = pl.BlockSpec((1, 1, st.block_q, G, dh),
                          lambda b, h, j, i: (b, h, i, 0, 0))
    row_spec = pl.BlockSpec((1, 1, st.block_q, G),
                            lambda b, h, j, i: (b, h, i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1,), lambda b, h, j, i: (b,)),
                  q_spec, kv_spec, kv_spec, row_spec, row_spec, q_spec],
        out_specs=[kv_spec, kv_spec],
        out_shape=[jax.ShapeDtypeStruct((B, KVH, S, dh), jnp.float32),
                   jax.ShapeDtypeStruct((B, KVH, S, dh), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((st.block_k, dh), jnp.float32),
                        pltpu.VMEM((st.block_k, dh), jnp.float32)],
        interpret=st.interpret,
    )(L_arr, q, k, v, lse, delta, do)


# ---------------------------------------------------------- custom VJP ----
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_attention(st: Static, q, k, v, L_arr):
    out, _ = _fwd_call(st, q, k, v, L_arr)
    return out


def _flash_attention_fwd(st: Static, q, k, v, L_arr):
    out, lse = _fwd_call(st, q, k, v, L_arr)
    # residuals are O(S*dh) — no score matrices survive the forward
    return out, (q, k, v, L_arr, out, lse)


def _flash_attention_bwd(st: Static, res, do):
    q, k, v, L_arr, out, lse = res
    # delta = rowsum(dO * O): O(S*dh) elementwise work, done outside the
    # kernels so both backward passes read it as a [B,KVH,S,G] stream
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)
    dq = _bwd_dq_call(st, q, k, v, L_arr, lse, delta, do)
    dk, dv = _bwd_dkv_call(st, q, k, v, L_arr, lse, delta, do)
    # integer lengths take a float0 cotangent (non-differentiable operand)
    dL = np.zeros(np.shape(L_arr), jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), dL)


_flash_attention.defvjp(_flash_attention_fwd, _flash_attention_bwd)


def flash_attention(q, k, v, lengths, *, block_q: int = 128,
                    block_k: int = 128, window: int = 0, softcap: float = 0.0,
                    causal: bool = True, interpret: bool = True):
    """q: [B, KVH, S, G, dh]; k, v: [B, KVH, S, dh]; lengths: int or [B]
    int32 (per-row valid KV prefix).

    Returns [B, KVH, S, G, dh] attention output: for query position t,
    softmax over key positions p with p < lengths[b], p <= t (causal) and
    t - window < p (when window > 0), with optional pre-mask tanh
    softcapping of the logits and f32 accumulation.

    Differentiable: ``jax.grad`` through this function runs the
    recompute-based backward kernels (module docstring) — the forward saves
    only O and the per-row logsumexp.
    """
    B, KVH, S, G, dh = q.shape
    assert k.shape == (B, KVH, S, dh), (q.shape, k.shape)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    L_arr = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32).reshape(-1),
                             (B,))
    st = Static(block_q=int(block_q), block_k=int(block_k),
                window=int(window), softcap=float(softcap),
                causal=bool(causal), interpret=bool(interpret))
    return _flash_attention(st, q, k, v, L_arr)
