"""GradIP blocked-reduction Pallas kernel.

GradIP_t = g_t * <gp, z_t> over the sparse coordinates (Definition 2.3).
The dot product is computed as a grid-sequential VMEM reduction with an
f32 accumulator tile; the scalar g multiplies at the end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_R = 256


def _gradip_kernel(gp_ref, z_ref, g_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    partial = jnp.sum(gp_ref[...].astype(jnp.float32)
                      * z_ref[...].astype(jnp.float32))
    out_ref[0, 0] += partial

    @pl.when(i == pl.num_programs(0) - 1)
    def _final():
        out_ref[0, 0] *= g_ref[0]


def gradip_reduce(gp, z, g, *, block_r: int = BLOCK_R, interpret: bool = True):
    """gp, z: [R, 128]; g: scalar. Returns g * sum(gp * z) as f32 scalar."""
    R, C = gp.shape
    assert C == LANE and R % block_r == 0, (gp.shape, block_r)
    grid = (R // block_r,)
    spec = pl.BlockSpec((block_r, LANE), lambda i: (i, 0))
    g_arr = jnp.asarray(g, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _gradip_kernel,
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(gp, z, g_arr)
    return out[0, 0]
