"""chatglm3-6b — RoPE 2d (partial rotary), GQA [arXiv:2406.12793].

28L d_model=4096 32H (kv=2) d_ff=13696 vocab=65024.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    rope_style="partial",
    rope_partial_factor=0.5,
    qkv_bias=True,
    norm_eps=1e-5,
    source="arXiv:2406.12793",
)
