"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the full
configs are exercised via the dry-run (ShapeDtypeStruct lowering only) and each
family also provides a ``reduced()`` variant (<=2 layers, d_model<=512,
<=4 experts) that is instantiated for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 2.0
    router_aux_weight: float = 0.01
    n_shared_experts: int = 0  # shared (always-on) experts, kimi-style


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM hyper-params."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block hyper-params (mLSTM chunkwise + sLSTM recurrent)."""
    n_heads: int = 4
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    chunk_size: int = 64
    conv_dim: int = 4


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (whisper) archs. Input comes from a stub
    frontend producing precomputed frame embeddings."""
    n_layers: int = 12
    n_frames: int = 1500


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # attention flavour
    rope_style: str = "full"  # full | partial | none
    rope_theta: float = 10_000.0
    rope_partial_factor: float = 0.5  # for rope_style == partial (chatglm "2d")
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_softcap: float = 0.0  # 0 -> disabled
    final_softcap: float = 0.0
    sliding_window: int = 0  # 0 -> disabled; used by 'local' layers
    post_norms: bool = False  # gemma2 sandwich norms
    # layer mixing: a repeating pattern of (mixer, ffn) pairs; the full stack is
    # n_layers == len(pattern) * n_periods and is scanned over periods.
    # mixer in {attn, local_attn, mamba, mlstm, slstm}; ffn in {dense, moe, none}
    layer_pattern: Tuple[Tuple[str, str], ...] = (("attn", "dense"),)
    act: str = "silu"  # silu (gated) | gelu (non-gated)
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scaling
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encoder: Optional[EncoderConfig] = None
    # modality frontend stub: none | audio_stub | vision_stub
    frontend: str = "none"
    n_patches: int = 256  # vision stub patch count
    # LoRA adapters (for the LoRA-FedZO baseline); 0 disables
    lora_rank: int = 0
    lora_alpha: float = 16.0
    # citation for the config
    source: str = ""
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period={self.period}")
        return self.n_layers // self.period

    @property
    def supports_long_context(self) -> bool:
        """True if the arch has a sub-quadratic (windowed / recurrent) path for
        every layer's mixer — gate for the long_500k shape."""
        ok = {"mamba", "mlstm", "slstm", "local_attn"}
        full_attn = [m for m, _ in self.layer_pattern if m not in ok]
        # gemma2: half the layers are full ("global") attention but the arch
        # ships a windowed variant; we allow archs whose pattern contains at
        # least one windowed/recurrent mixer type.
        has_subquadratic = len(full_attn) < len(self.layer_pattern)
        return has_subquadratic and self.frontend == "none" and self.encoder is None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: <=2 periods, d_model<=256,
        <=4 experts, tiny vocab."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        head_dim = min(self.resolved_head_dim, 64)
        kw = dict(
            name=self.name + "-reduced",
            n_layers=self.period * min(self.n_periods, 2),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else 0,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 256),
            )
        if self.encoder is not None:
            kw["encoder"] = dataclasses.replace(
                self.encoder, n_layers=min(self.encoder.n_layers, 2), n_frames=16)
        if self.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(
                self.xlstm, n_heads=min(self.xlstm.n_heads, 2), chunk_size=16)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=8)
        if self.frontend == "vision_stub":
            kw["n_patches"] = 8
        return self.replace(**kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def reduced(self, seq_len: int = 32, global_batch: int = 4) -> "InputShape":
        return InputShape(self.name + "-reduced", seq_len, global_batch, self.kind)


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pods

    @property
    def shape(self):
        if self.pods > 1:
            return (self.pods, self.data, self.model)
        return (self.data, self.model)

    @property
    def axis_names(self):
        if self.pods > 1:
            return ("pod", "data", "model")
        return ("data", "model")

    @property
    def batch_axes(self):
        return ("pod", "data") if self.pods > 1 else ("data",)


@dataclass(frozen=True)
class FLConfig:
    """Federated-learning hyper-params (paper §2.1 / Alg. 1-3)."""
    n_clients: int = 8
    rounds: int = 20
    local_steps: int = 1  # T
    lr: float = 1e-3
    eps: float = 1e-3  # ZO perturbation magnitude
    density: float = 1e-3  # u
    mask_kind: str = "sensitivity"  # sensitivity | magnitude | random | dense | lora
    seed: int = 0
    batch_size: int = 16
    # ZO hot-path execution route (core/dispatch.py): "auto" uses the fused
    # flat Pallas kernels when the layout supports it, else the pytree route.
    zo_backend: str = "auto"  # auto | pallas | ref
    # beyond-paper: K-direction ZO estimator per local step (core/zo.py);
    # clients then upload T*K scalars per round
    n_dirs: int = 1
    # MEERKAT-VP (Alg. 1) knobs — defaults follow Appendix C.1 Table 4
    vp_calibration_steps: int = 100
    vp_init_steps: int = 20
    vp_later_steps: int = 20
    vp_sigma: float = 1.0  # convergence threshold on |GradIP|
    vp_rho_later: float = 5.0  # initial-to-later ratio threshold
    vp_rho_quie: float = 0.5  # quiescent step ratio threshold
    # beyond-paper: interpret vp_sigma as a fraction of the client's
    # initial-phase |GradIP| (scale-free across model sizes / densities)
    vp_sigma_relative: bool = False
    # beyond-paper: FedAvgM-style server momentum on the aggregated sparse
    # update (0 = paper-faithful plain averaging)
    server_momentum: float = 0.0
    # fleet-scale rounds (DESIGN.md §12)
    # per-round participation fraction: < 1 enables the seeded
    # ClientSampler (cohort size max(1, round(frac * K)))
    sample_frac: float = 1.0
    # weight cohort draws by client dataset size (uniform otherwise)
    sample_weighted: bool = False
    # uplink codec for the ZO scalars (core/quantize.py):
    # none | int8 | int4 [-nearest for deterministic rounding]
    quantize: str = "none"


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 1e-3
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 64
    optimizer: str = "sgd"
    seed: int = 0


# TPU v5e hardware constants for the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,            # B/s
    "ici_bw": 50e9,             # B/s per link
}
