"""Config registry: assigned architectures, paper models, input shapes."""
from __future__ import annotations

from repro.configs.base import (HW, EncoderConfig, FLConfig, InputShape,
                                MeshConfig, ModelConfig, MoEConfig, SSMConfig,
                                TrainConfig, XLSTMConfig)
from repro.configs.shapes import SHAPES, get_shape

from repro.configs.chatglm3_6b import CONFIG as CHATGLM3_6B
from repro.configs.gemma2_27b import CONFIG as GEMMA2_27B
from repro.configs.jamba_1_5_large_398b import CONFIG as JAMBA_1_5_LARGE
from repro.configs.kimi_k2_1t_a32b import CONFIG as KIMI_K2
from repro.configs.paper_models import GEMMA2_2B, LLAMA32_1B, QWEN2_1_5B
from repro.configs.phi35_moe_42b_a6_6b import CONFIG as PHI35_MOE
from repro.configs.pixtral_12b import CONFIG as PIXTRAL_12B
from repro.configs.qwen2_7b import CONFIG as QWEN2_7B
from repro.configs.qwen3_4b import CONFIG as QWEN3_4B
from repro.configs.whisper_small import CONFIG as WHISPER_SMALL
from repro.configs.xlstm_350m import CONFIG as XLSTM_350M

ASSIGNED = {
    c.name: c
    for c in (XLSTM_350M, WHISPER_SMALL, QWEN3_4B, KIMI_K2, PHI35_MOE,
              QWEN2_7B, CHATGLM3_6B, JAMBA_1_5_LARGE, GEMMA2_27B, PIXTRAL_12B)
}

PAPER_MODELS = {c.name: c for c in (LLAMA32_1B, QWEN2_1_5B, GEMMA2_2B)}

REGISTRY = {**ASSIGNED, **PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-reduced"):
        return get_config(name[: -len("-reduced")]).reduced()
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs():
    return sorted(ASSIGNED)


__all__ = [
    "ASSIGNED", "PAPER_MODELS", "REGISTRY", "SHAPES", "get_config",
    "get_shape", "list_archs", "ModelConfig", "MoEConfig", "SSMConfig",
    "XLSTMConfig", "EncoderConfig", "InputShape", "MeshConfig", "FLConfig",
    "TrainConfig", "HW",
]
