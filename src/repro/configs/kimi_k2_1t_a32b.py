"""kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2].

61L d_model=7168 64H (kv=8) vocab=163840; MoE 384 experts top-8 with
d_ff_expert=2048 (spec's d_ff column), plus 1 shared expert.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab=163840,
    layer_pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048,
                  capacity_factor=2.0, n_shared_experts=1),
    source="arXiv:2501.kimi2",
)
