"""jamba-1.5-large-398b — Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887].

72L d_model=8192 64H (kv=8) d_ff=24576 vocab=65536, MoE 16e top-2 on every
other layer.  Period of 8 layers: 1 attention + 7 Mamba, MoE FFN alternating
with dense FFN.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

_PATTERN = (
    ("attn", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
    ("mamba", "dense"),
    ("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    rope_style="none",  # jamba attention layers use no positional encoding
    norm_eps=1e-5,
    layer_pattern=_PATTERN,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, capacity_factor=2.0),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2403.19887",
)
