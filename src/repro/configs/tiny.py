"""Tiny configs for CPU simulations / unit tests."""
from repro.configs.base import ModelConfig

TINY = ModelConfig(
    name="tiny-dense",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    tie_embeddings=True,
    source="test",
)

TINY_LORA = TINY.replace(name="tiny-lora", lora_rank=4)
