"""The paper's own models (Gemma-2-2b, Qwen2-1.5B, Llama-3.2-1B) as configs.

Benchmarks use their ``reduced()`` variants on CPU; the full configs document
the paper's experimental setting and can be dry-run like the assigned archs.
"""
from repro.configs.base import ModelConfig

LLAMA32_1B = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
    source="arXiv:2407.21783",
)

QWEN2_1_5B = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=True,
    source="arXiv:2407.10671",
)

GEMMA2_2B = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=18432,
    vocab=256000,
    act="gelu",
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    layer_pattern=(("local_attn", "dense"), ("attn", "dense")),
    source="arXiv:2408.00118",
)
