"""gemma2-27b — local+global alternating attention, logit softcap [arXiv:2408.00118].

46L d_model=4608 32H (kv=16) d_ff=36864 vocab=256000; sliding window 4096 on
local layers, attn softcap 50, final logit softcap 30, sandwich norms.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    act="gelu",
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    layer_pattern=(("local_attn", "dense"), ("attn", "dense")),
    source="arXiv:2408.00118",
)
