"""whisper-small — enc-dec, conv frontend (stub) [arXiv:2212.04356].

12L (decoder; + 12L encoder) d_model=768 12H d_ff=3072 vocab=51865.
The mel-spectrogram + conv feature extractor is a stub: ``input_specs``
provides precomputed 1500-frame embeddings of shape (B, 1500, 768).
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    rope_style="none",  # learned absolute positions
    act="gelu_plain",
    norm="layernorm",
    norm_eps=1e-5,
    encoder=EncoderConfig(n_layers=12, n_frames=1500),
    frontend="audio_stub",
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
