"""qwen3-4b — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936, head_dim=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    rope_theta=1_000_000.0,
    qk_norm=True,
    source="hf:Qwen/Qwen3-8B",
)
