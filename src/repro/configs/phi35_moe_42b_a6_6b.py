"""phi3.5-moe-42b-a6.6b — 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (kv=8) d_ff=6400 vocab=32064, MoE 16e top-2.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab=32064,
    norm="layernorm",
    norm_eps=1e-5,
    layer_pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400, capacity_factor=2.0),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
