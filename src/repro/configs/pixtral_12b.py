"""pixtral-12b — pixtral-ViT + mistral-nemo backbone [hf:mistralai/Pixtral-12B-2409].

40L d_model=5120 32H (kv=8) d_ff=14336 vocab=131072.  The ViT vision encoder
+ projector are a stub: ``input_specs`` provides 256 precomputed patch
embeddings per example, prepended to the token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    n_patches=256,
    source="hf:mistralai/Pixtral-12B-2409",
)
