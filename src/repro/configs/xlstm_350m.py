"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L d_model=1024 4H d_ff=0 vocab=50304.  7:1 mLSTM:sLSTM interleave; no
separate FFN (up-projections live inside the blocks), hence d_ff=0.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    rope_style="none",
    norm="layernorm",
    layer_pattern=tuple([("mlstm", "none")] * 7 + [("slstm", "none")]),
    xlstm=XLSTMConfig(n_heads=4, chunk_size=64),
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
