from repro.utils.jaxpr import max_square_dims
from repro.utils.tree import (flat_size, leaf_paths, tree_concat_flat,
                              tree_from_flat, tree_zeros_like_flat)
