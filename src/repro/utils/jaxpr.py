"""Back-compat shim: the jaxpr structural checks moved into the static
analyzer (``repro.analysis.walk``, DESIGN.md §10) so tests, benchmarks
and the rule engine share one walker.  Import from ``repro.analysis``
in new code."""
from __future__ import annotations

from repro.analysis.walk import max_square_dims  # noqa: F401
