"""Jaxpr structural checks shared by tests and benchmarks."""
from __future__ import annotations

import jax


def max_square_dims(jaxpr, S: int) -> int:
    """Largest count of >= S dims on any intermediate aval, walking every
    sub-jaxpr (scan/cond bodies, pallas_call kernels).

    The no-[S, S]-intermediate proof for the blockwise attention routes
    (tests/test_attn_backends.py, benchmarks/attn_bench.py): a forward
    whose jaxpr never holds two >= S dims on one buffer cannot have
    materialized the score matrix."""
    worst = 0

    def walk(jx):
        nonlocal worst
        for eqn in jx.eqns:
            for var in eqn.outvars:
                shape = getattr(var.aval, "shape", ())
                worst = max(worst, sum(1 for d in shape if d >= S))
            for p in eqn.params.values():
                for sub in jax.tree_util.tree_leaves(
                        p, is_leaf=lambda x: isinstance(
                            x, (jax.extend.core.Jaxpr,
                                jax.extend.core.ClosedJaxpr))):
                    if isinstance(sub, jax.extend.core.ClosedJaxpr):
                        walk(sub.jaxpr)
                    elif isinstance(sub, jax.extend.core.Jaxpr):
                        walk(sub)

    walk(jaxpr.jaxpr)
    return worst
