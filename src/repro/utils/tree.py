"""Pytree <-> flat-vector utilities used by the ZO param-space machinery."""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def leaf_paths(tree: Any) -> List[str]:
    """Stable, human-readable path string per leaf (in tree_flatten order)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in flat]


def flat_size(tree: Any) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(tree)))


def tree_concat_flat(tree: Any) -> jnp.ndarray:
    """Concatenate all leaves into a single flat f32 vector."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])


def tree_from_flat(template: Any, vec: jnp.ndarray) -> Any:
    """Inverse of :func:`tree_concat_flat` given a shape template."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape))
        out.append(vec[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_zeros_like_flat(tree: Any) -> jnp.ndarray:
    return jnp.zeros((flat_size(tree),), jnp.float32)


def leaf_offsets(tree: Any) -> List[Tuple[str, int, int]]:
    """(path, offset, size) per leaf in flatten order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out, off = [], 0
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        out.append((jax.tree_util.keystr(path), off, n))
        off += n
    return out
