from repro.serving.engine import (CompileCache, ContinuousBatchingEngine,
                                  ServeEngine, generate)
