"""Serving engines: compile-cached generation and continuous batching.

Two layers:

* :func:`generate` + :class:`ServeEngine` — the naive flush engine kept as
  the benchmark baseline: collect requests, right-pad to a bucket, run one
  prefill + a fixed-length decode scan for the whole batch (every request
  rides to ``max(max_new_tokens)``).
* :class:`ContinuousBatchingEngine` — fixed-capacity decode *slots* over one
  shared cache: per-request prefill (bucketed, compile-cached) inserts a
  request into a free slot mid-decode, every decode step advances all active
  slots in a single compiled call, and finished slots retire early (their
  state frozen via ``decode_step(active=...)``) and free capacity for queued
  requests.

Correctness contract (regression-tested per arch): right-padded batched
generation with explicit per-sequence ``lengths`` produces the same greedy
tokens as running each request alone — see ``models/decode.prefill``.

All jitted callables are hoisted out of the per-flush path and cached by
shape key, so steady-state serving never re-traces (the compile-hit
counters are asserted in tests).

``decode_32k`` / ``long_500k`` dry-run shapes lower :func:`step_fn` (one
token against a seq_len cache); this module provides the runnable engine
for the small-scale demos, benchmarks, and tests.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, Hashable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as D
from repro.models.model import Model


def _frontend_stub(cfg, B: int) -> Dict:
    """Zero frontend embeddings for token-only serving requests."""
    extras = {}
    if cfg.frontend == "vision_stub":
        extras["patch_embeds"] = jnp.zeros((B, cfg.n_patches, cfg.d_model),
                                           jnp.float32)
    if cfg.frontend == "audio_stub":
        nf = cfg.encoder.n_frames
        extras["audio_embeds"] = jnp.zeros((B, nf, cfg.d_model), jnp.float32)
    return extras


def _frontend_extra(cfg) -> int:
    return cfg.n_patches if cfg.frontend == "vision_stub" else 0


# ------------------------------------------------------------- generate ----
def _model_jit_cache(model: Model) -> Dict:
    """Per-model cache of jitted serving callables.

    Stored on the Model instance (not a module-global lru) so the compiled
    executables live exactly as long as the model they close over."""
    cache = getattr(model, "_serve_jit_cache", None)
    if cache is None:
        cache = model._serve_jit_cache = {}
    return cache


def _prefill_jit(model: Model, S_max: int):
    """Jitted prefill for (model, S_max); jit's own cache keys the batch
    shapes and the lengths=None/array treedef."""
    cache = _model_jit_cache(model)
    key = ("prefill", S_max)
    if key not in cache:
        cache[key] = jax.jit(lambda params, batch, lengths: model.prefill(
            params, batch, S_max=S_max, lengths=lengths))
    return cache[key]


def _decode_loop(model: Model, temperature: float, n_steps: int):
    """Jitted fixed-length decode scan for (model, temperature, n_steps).

    Hoisted out of :func:`generate` so repeated calls at identical shapes
    reuse one jit cache entry instead of re-tracing a fresh closure per
    call (the per-flush recompile bug)."""
    cache = _model_jit_cache(model)
    key = ("decode_loop", temperature, n_steps)
    if key in cache:
        return cache[key]

    def pick(logits, key):
        if temperature > 0:
            return jax.random.categorical(key, logits / temperature, axis=-1)
        return jnp.argmax(logits, axis=-1)

    def run(params, logits, cache, key):
        def step(carry, _):
            logits, cache, key = carry
            key, sub = jax.random.split(key)
            tok = pick(logits, sub).astype(jnp.int32)
            logits, cache = model.decode_step(params, tok, cache)
            return (logits, cache, key), tok

        (_, cache, _), toks = jax.lax.scan(step, (logits, cache, key),
                                           None, length=n_steps)
        return toks

    fn = cache[key] = jax.jit(run)
    return fn


def generate(model: Model, params, batch: Dict, max_new_tokens: int,
             S_max: int = 0, temperature: float = 0.0, key=None,
             lengths=None):
    """Prefill the prompt then decode ``max_new_tokens`` greedily (or with
    temperature sampling).  Returns int32 [B, max_new_tokens].

    ``lengths``: per-row valid token counts for right-padded batches (see
    ``models/decode.prefill``)."""
    prompt = batch["tokens"]
    B, S = prompt.shape
    S_max = S_max or (S + _frontend_extra(model.cfg) + max_new_tokens)
    logits, cache = _prefill_jit(model, S_max)(params, batch, lengths)
    key = key if key is not None else jax.random.key(0)
    toks = _decode_loop(model, float(temperature),
                        int(max_new_tokens))(params, logits, cache, key)
    return toks.swapaxes(0, 1)  # [B, T]


# ------------------------------------------------------- compile cache -----
class CompileCache:
    """Shape-keyed cache of jitted callables with hit/miss counters.

    The counters are the steady-state guarantee: once every shape bucket
    has been seen, ``misses`` must stop growing (asserted in tests)."""

    def __init__(self):
        self._fns: Dict[Hashable, Callable] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key: Hashable, build: Callable[[], Callable]) -> Callable:
        fn = self._fns.get(key)
        if fn is None:
            self.misses += 1
            fn = self._fns[key] = build()
        else:
            self.hits += 1
        return fn

    @property
    def n_entries(self) -> int:
        return len(self._fns)


# ------------------------------------------------------- naive engine ------
class ServeEngine:
    """Minimal batched-request engine (the naive baseline): collects
    requests up to a batch size, right-pads prompts to a bucket, runs one
    prefill + fixed-length decode for the whole batch."""

    def __init__(self, model: Model, params, max_batch: int = 8,
                 bucket: int = 64):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.queue = []

    def submit(self, tokens: np.ndarray, max_new_tokens: int = 16):
        self.queue.append((np.asarray(tokens, np.int32), max_new_tokens))

    def flush(self):
        """Run all queued requests in padded batches; returns list of
        generated-token arrays in submit order."""
        out = []
        while self.queue:
            chunk, self.queue = (self.queue[:self.max_batch],
                                 self.queue[self.max_batch:])
            lens = [len(t) for t, _ in chunk]
            S = ((max(lens) + self.bucket - 1) // self.bucket) * self.bucket
            new = max(m for _, m in chunk)
            toks = np.zeros((len(chunk), S), np.int32)
            for i, (t, _) in enumerate(chunk):
                toks[i, :len(t)] = t  # right-pad; masked via lengths
            batch = {"tokens": jnp.asarray(toks),
                     **_frontend_stub(self.model.cfg, len(chunk))}
            gen = generate(self.model, self.params, batch, new,
                           lengths=jnp.asarray(lens, jnp.int32))
            for i, (_, m) in enumerate(chunk):
                out.append(np.asarray(gen[i, :m]))
        return out


# ------------------------------------------- continuous-batching engine ----
@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    remaining: int = 0
    t_submit: float = 0.0
    t_first: Optional[float] = None  # first-token wall time (TTFT end)


class ContinuousBatchingEngine:
    """Slot-based continuous batching over one fixed-capacity cache.

    * ``max_slots`` decode slots share a [max_slots, S_max] cache; each slot
      tracks its own position (``cache['pos']`` is per-row).
    * Admission: a queued request prefills alone (prompt right-padded to a
      ``bucket`` multiple, exact length passed through) and is inserted
      into a free slot — including slots freed mid-decode.
    * One compiled decode *burst* advances every active slot by
      ``min(remaining)`` tokens (bounded to a fixed ladder of scan lengths
      so the compile cache stays finite).  Budgets are host-known, so no
      slot can finish mid-burst and no admission opportunity is missed —
      burst scheduling is semantically identical to stepping one token at
      a time, without a host round-trip per token.
    * Finished slots exit early (state frozen via
      ``decode_step(active=...)``) instead of riding to the batch maximum.
    * All jitted functions live in a :class:`CompileCache`; at steady state
      (all prompt buckets seen) no call re-traces.

    ``decode_backend`` selects the decode-attention route
    ("pallas" | "ref" | "auto", see ``models/layers.resolve_decode_backend``);
    ``attn_backend`` the grouped prefill-into-slot forward-attention route
    ("pallas" | "online" | "dense" | "auto", see
    ``models/layers.resolve_attn_backend``).
    """

    BURSTS = (32, 24, 16, 12, 8, 6, 4, 3, 2, 1)  # compiled scan lengths

    def __init__(self, model: Model, params, max_slots: int = 4,
                 S_max: int = 128, bucket: int = 16,
                 decode_backend: str = "auto", attn_backend: str = "auto",
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.cfg = model.cfg
        self.ctx = dataclasses.replace(model.ctx,
                                       decode_backend=decode_backend,
                                       attn_backend=attn_backend)
        self.params = params
        self.max_slots = max_slots
        self.S_max = S_max
        self.bucket = bucket
        self.temperature = temperature
        dtype = params["embed"].dtype
        self.cache = D.init_cache(self.cfg, max_slots, S_max, dtype=dtype)
        self.last_logits = jnp.zeros((max_slots, self.cfg.vocab), jnp.float32)
        self.slots: List[Optional[Request]] = [None] * max_slots
        self.pending: deque = deque()
        self.done: Dict[int, Request] = {}
        self.compile_cache = CompileCache()
        self._next_rid = 0
        self._key = jax.random.key(seed)
        self.n_decode_steps = 0
        # bursts whose token values haven't been fetched yet: scheduling
        # never reads token *values*, so fetches defer until a TTFT needs
        # recording or results are collected — deferred bursts pipeline
        # on-device without a host round-trip each
        self._deferred: List = []

    # ---------------------------------------------------------- submit ----
    def submit(self, tokens: np.ndarray, max_new_tokens: int = 16) -> int:
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        budget = self.S_max - _frontend_extra(self.cfg) - max_new_tokens
        if len(tokens) > budget:
            raise ValueError(
                f"prompt of {len(tokens)} tokens + {max_new_tokens} new "
                f"exceeds S_max={self.S_max}")
        req = Request(rid=self._next_rid, tokens=tokens,
                      max_new_tokens=max_new_tokens,
                      remaining=max_new_tokens, t_submit=time.perf_counter())
        self._next_rid += 1
        self.pending.append(req)
        return req.rid

    # ------------------------------------------------- jitted builders ----
    def _prefill_fn(self, S_pad: int, g: int):
        """Fused prefill-into-slots: one compiled call per (prompt bucket,
        group size) right-pad-prefills ``g`` requests together AND scatters
        their caches/logits into their slots — admission costs one dispatch
        per group and the sub-cache never round-trips through host-visible
        buffers.  Keys are bounded: g <= max_slots, buckets <= S_max/bucket.
        """
        cfg, ctx, S_max = self.cfg, self.ctx, self.S_max

        def build():
            def fn(params, tokens, lengths, cache, last_logits, slots):
                batch = {"tokens": tokens, **_frontend_stub(cfg, g)}
                logits, sub = D.prefill(params, batch, cfg, ctx, S_max=S_max,
                                        lengths=lengths)

                def ins(big, small):
                    return big.at[:, slots].set(small.astype(big.dtype))

                stack = jax.tree.map(ins, cache["stack"], sub["stack"])
                pos = cache["pos"].at[slots].set(sub["pos"])
                ll = last_logits.at[slots].set(logits)
                return {"stack": stack, "pos": pos}, ll
            return jax.jit(fn)

        return self.compile_cache.get(("prefill", S_pad, g), build)

    def _decode_fn(self, n_steps: int, tailed: bool):
        """Compiled decode burst of ``n_steps``.

        ``tailed=False`` (the queue-limited case, burst <= min remaining):
        no slot can exhaust its budget mid-burst, so the scan carries no
        per-step activity masking — each step costs exactly a naive decode
        step.  ``tailed=True`` (the drain case): slot b freezes once
        ``i >= remaining[b]``, exactly as if stepped one token at a time,
        so short slots retire device-side while long ones run on."""
        cfg, ctx, temperature = self.cfg, self.ctx, self.temperature

        sampled = temperature > 0

        def build():
            # signature varies with the variant so the hot greedy/uniform
            # path ships no dead operands (each transfer costs real time at
            # tiny-model step granularity)
            def fn(params, last_logits, cache, remaining=None, key=None):
                def step(carry, i):
                    logits, cache, key = carry
                    if sampled:
                        key, sub = jax.random.split(key)
                        tok = jax.random.categorical(
                            sub, logits / temperature, axis=-1)
                    else:
                        tok = jnp.argmax(logits, axis=-1)
                    tok = tok.astype(jnp.int32)
                    active = (i < remaining) if tailed else None
                    logits, cache = D.decode_step(params, tok, cache, cfg,
                                                  ctx, active=active)
                    return (logits, cache, key), tok

                (logits, cache, _), toks = jax.lax.scan(
                    step, (last_logits, cache, key), jnp.arange(n_steps))
                return toks, logits, cache  # toks: [n_steps, B]
            return jax.jit(fn)

        return self.compile_cache.get(("decode", n_steps, tailed), build)

    # ------------------------------------------------------------ step ----
    def _admit(self):
        free = [i for i, r in enumerate(self.slots) if r is None]
        take = min(len(free), len(self.pending))
        if not take:
            return
        items = [(self.pending.popleft(), free[i]) for i in range(take)]
        # one prefill per admission wave: everyone pads to the wave's
        # largest bucket (dispatch count beats the few wasted pad columns;
        # right-pad masking keeps the extra columns semantically inert)
        g = len(items)
        S_pad = max(-(-max(len(req.tokens), 1) // self.bucket) * self.bucket
                    for req, _ in items)
        toks = np.zeros((g, S_pad), np.int32)
        for i, (req, _) in enumerate(items):
            toks[i, :len(req.tokens)] = req.tokens
        lengths = np.array([len(r.tokens) for r, _ in items], np.int32)
        slots = np.array([s for _, s in items], np.int32)
        self.cache, self.last_logits = self._prefill_fn(S_pad, g)(
            self.params, jnp.asarray(toks), jnp.asarray(lengths),
            self.cache, self.last_logits, jnp.asarray(slots))
        for req, slot in items:
            self.slots[slot] = req

    def step(self) -> bool:
        """Admit pending requests into free slots, then advance every
        active slot by one decode burst.  Returns False when drained.

        While requests are queued, the burst stops at the smallest
        remaining budget so a freed slot admits immediately; once the
        queue is empty there is nothing to admit, so the burst runs to the
        *largest* remaining budget and slots retire device-side mid-burst
        (``active = i < remaining`` inside the scan)."""
        self._admit()
        reqs = [r for r in self.slots if r is not None]
        if not reqs:
            return False
        lo = min(r.remaining for r in reqs)
        k = lo if self.pending else max(r.remaining for r in reqs)
        burst = next(b for b in self.BURSTS if b <= k)
        # the cheap uniform burst (no per-step masking) requires every slot
        # live for the whole burst: no budget runs out mid-burst AND no
        # empty slot decodes placeholder tokens (which must stay masked out
        # of MoE capacity dispatch)
        tailed = burst > lo or len(reqs) < self.max_slots
        kwargs = {}
        if tailed:
            kwargs["remaining"] = jnp.asarray(
                np.array([r.remaining if r is not None else 0
                          for r in self.slots], np.int32))
        if self.temperature > 0:
            self._key, kwargs["key"] = jax.random.split(self._key)
        toks, self.last_logits, self.cache = self._decode_fn(burst, tailed)(
            self.params, self.last_logits, self.cache, **kwargs)
        self.n_decode_steps += burst
        first_timers = any(r is not None and r.t_first is None
                           for r in self.slots)
        takes = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            take = min(burst, req.remaining)
            takes.append((req, slot, take))
            req.remaining -= take
            if req.remaining == 0:
                self.done[req.rid] = req
                self.slots[slot] = None  # early exit: slot freed mid-decode
        self._deferred.append((toks, takes))
        if first_timers:
            self._collect()  # block now: these requests' TTFT ends here
        return True

    def _collect(self):
        """Materialize deferred burst tokens (blocks on the device)."""
        for toks, takes in self._deferred:
            toks_np = np.asarray(toks)  # [burst, B]
            now = time.perf_counter()
            for req, slot, take in takes:
                if req.t_first is None:
                    req.t_first = now
                req.out.extend(int(t) for t in toks_np[:take, slot])
        self._deferred.clear()

    def run(self) -> List[np.ndarray]:
        """Drain queue + slots; returns the tokens of requests completed by
        THIS call, in submit order (a reused engine keeps earlier waves in
        ``self.done`` for stats but does not return them again)."""
        already = set(self.done)
        while self.step():
            pass
        self._collect()
        return [np.asarray(self.done[rid].out, np.int32)
                for rid in sorted(self.done) if rid not in already]

    # ------------------------------------------------------------ stats ----
    @property
    def stats(self) -> Dict[str, float]:
        reqs = self.done.values()
        ttfts = [r.t_first - r.t_submit for r in reqs if r.t_first is not None]
        return {
            "completed": len(self.done),
            "decode_steps": self.n_decode_steps,
            "compile_hits": self.compile_cache.hits,
            "compile_misses": self.compile_cache.misses,
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else 0.0,
        }
