"""Batched serving engine: prefill + greedy/temperature decode loop.

``decode_32k`` / ``long_500k`` dry-run shapes lower :func:`step_fn` (one
token against a seq_len cache); this module provides the runnable engine for
the small-scale demos and tests.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


def generate(model: Model, params, batch: Dict, max_new_tokens: int,
             S_max: int = 0, temperature: float = 0.0, key=None):
    """Prefill the prompt then decode ``max_new_tokens`` greedily (or with
    temperature sampling).  Returns int32 [B, max_new_tokens]."""
    prompt = batch["tokens"]
    B, S = prompt.shape
    extra = (model.cfg.n_patches
             if model.cfg.frontend == "vision_stub" else 0)
    S_max = S_max or (S + extra + max_new_tokens)
    logits, cache = model.prefill(params, batch, S_max=S_max)
    key = key if key is not None else jax.random.key(0)

    def pick(logits, key):
        if temperature > 0:
            return jax.random.categorical(key, logits / temperature, axis=-1)
        return jnp.argmax(logits, axis=-1)

    @jax.jit
    def step(carry, _):
        logits, cache, key = carry
        key, sub = jax.random.split(key)
        tok = pick(logits, sub).astype(jnp.int32)
        logits, cache = model.decode_step(params, tok, cache)
        return (logits, cache, key), tok

    (_, cache, _), toks = jax.lax.scan(step, (logits, cache, key),
                                       None, length=max_new_tokens)
    return toks.swapaxes(0, 1)  # [B, T]


class ServeEngine:
    """Minimal batched-request engine: collects requests up to a batch size,
    pads prompts to a bucket, runs prefill+decode."""

    def __init__(self, model: Model, params, max_batch: int = 8,
                 bucket: int = 64):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.bucket = bucket
        self.queue = []

    def submit(self, tokens: np.ndarray, max_new_tokens: int = 16):
        self.queue.append((np.asarray(tokens, np.int32), max_new_tokens))

    def flush(self):
        """Run all queued requests in padded batches; returns list of
        generated-token arrays in submit order."""
        out = []
        while self.queue:
            chunk, self.queue = (self.queue[:self.max_batch],
                                 self.queue[self.max_batch:])
            S = max(len(t) for t, _ in chunk)
            S = ((S + self.bucket - 1) // self.bucket) * self.bucket
            new = max(m for _, m in chunk)
            toks = np.zeros((len(chunk), S), np.int32)
            for i, (t, _) in enumerate(chunk):
                toks[i, S - len(t):] = t  # left-pad
            batch = {"tokens": jnp.asarray(toks)}
            if self.model.cfg.frontend == "vision_stub":
                batch["patch_embeds"] = jnp.zeros(
                    (len(chunk), self.model.cfg.n_patches,
                     self.model.cfg.d_model), jnp.float32)
            if self.model.cfg.frontend == "audio_stub":
                nf = self.model.cfg.encoder.n_frames
                batch["audio_embeds"] = jnp.zeros(
                    (len(chunk), nf, self.model.cfg.d_model), jnp.float32)
            gen = generate(self.model, self.params, batch, new)
            for i, (_, m) in enumerate(chunk):
                out.append(np.asarray(gen[i, :m]))
        return out
