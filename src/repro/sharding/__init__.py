from repro.sharding.fl import FLShardPlan, make_fl_plan
from repro.sharding.rules import (batch_specs, cache_specs, fsdp_only_specs,
                                  mask_specs, param_specs, token_spec)
