from repro.sharding.rules import (batch_specs, cache_specs, mask_specs,
                                  param_specs, token_spec)
