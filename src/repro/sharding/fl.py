"""Sharding plan for the federated ZO round (the mesh route of
``core/server.FederatedZO`` and ``core/fl_step``).

The round's distributed layout is deliberately simple, because the MEERKAT
step has no backward pass and its only cross-client communication is
scalar aggregation (the paper's point):

* **clients** (the leading ``[K]`` axis of every stacked batch) shard over
  the mesh batch axes — ``('pod', 'data')`` under ``rule="tp"``, the
  *whole* mesh under the default ``rule="fsdp"`` (ZO has no tensor
  parallelism to spend the ``'model'`` axis on, so it too becomes a
  client shard; rules.py docstring).  Pure data parallelism: each device
  runs its clients' full T-step local loops.
* **parameters** shard per ``sharding/rules.py``.  The default rule is
  ``"fsdp"`` (:func:`repro.sharding.rules.fsdp_only_specs`): every weight
  leaf is sharded over *all* mesh axes on its largest divisible dim and
  GSPMD all-gathers it at the point of use.  ZO runs no backward, so
  Megatron tensor parallelism (``rule="tp"``,
  :func:`repro.sharding.rules.param_specs`) only buys per-layer activation
  all-reduces the round does not need — and, crucially, row-parallel TP
  splits matmul contraction dims, which changes float summation order and
  breaks *bit* parity with the single-device path (DESIGN.md §9).  FSDP
  keeps every per-client matmul whole, so the sharded round is
  bit-identical to the unsharded one; the parity suite
  (``tools/fl_mesh_parity.py``) pins this down.
* **scalars** — per-step PRNG keys, the uploaded projected gradients
  ``g_k^t``, GradIP trajectories and the aggregated sparse update — stay
  replicated / host-side.  The server-side virtual-path replay therefore
  consumes bit-identical inputs regardless of mesh shape, which is why
  seed-replay reconstruction stays exact under sharding.

``FLShardPlan`` carries the mesh + rule and places concrete arrays;
``core/server.FederatedZO`` accepts one via ``plan=``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax

from repro.configs.base import MeshConfig
from repro.sharding.rules import fsdp_only_specs, param_specs

P = jax.sharding.PartitionSpec

PARAM_RULES = ("fsdp", "tp", "replicate")


@dataclasses.dataclass(frozen=True)
class FLShardPlan:
    """How one federated round maps onto a device mesh.

    ``mesh``     — a ``jax.sharding.Mesh`` (see ``launch/mesh.py``).
    ``mesh_cfg`` — its :class:`MeshConfig` (axis sizes/names).
    ``rule``     — parameter sharding rule: ``"fsdp"`` (default,
    bit-exact vs single device), ``"tp"`` (Megatron specs from
    ``rules.param_specs`` — allclose, not bit-exact: row-parallel
    contractions reorder float sums), or ``"replicate"``.
    """
    mesh: Any
    mesh_cfg: MeshConfig
    rule: str = "fsdp"

    def __post_init__(self):
        if self.rule not in PARAM_RULES:
            raise ValueError(
                f"rule must be one of {PARAM_RULES}, got {self.rule!r}")

    # -- basic wrappers ------------------------------------------------------
    @property
    def batch_axes(self):
        """Mesh axes acting as the FL-client/data axis.

        Under fleet-scale client sampling (DESIGN.md §12) this axis
        spans the round's **sampled cohort** (``m`` clients), not the
        full fleet ``K`` — divisibility and shard widths are governed by
        the cohort size the server actually runs per round.

        ``"fsdp"`` / ``"replicate"`` run no tensor parallelism, so *every*
        mesh axis is a data shard (the dry-run's ``zo_dp`` layout;
        rules.py docstring) — this is also what keeps the round bit-exact:
        no mesh axis ever splits a matmul contraction.  ``"tp"`` reserves
        the ``'model'`` axis for Megatron TP and shards clients over
        ``('pod', 'data')`` only."""
        if self.rule == "tp":
            return self.mesh_cfg.batch_axes
        return tuple(self.mesh_cfg.axis_names)

    @property
    def dp(self) -> int:
        """Data-parallel width: product of :attr:`batch_axes` sizes."""
        n = self.mesh_cfg.data * self.mesh_cfg.pods
        if self.rule != "tp":
            n *= self.mesh_cfg.model
        return n

    def named(self, spec: P) -> jax.sharding.NamedSharding:
        return jax.sharding.NamedSharding(self.mesh, spec)

    def replicated(self) -> jax.sharding.NamedSharding:
        return self.named(P())

    # -- parameter placement -------------------------------------------------
    def param_specs(self, params):
        """PartitionSpec pytree for ``params`` under :attr:`rule`."""
        if self.rule == "replicate":
            return jax.tree.map(lambda l: P(*([None] * l.ndim)), params)
        fn = fsdp_only_specs if self.rule == "fsdp" else param_specs
        return fn(None, params, self.mesh_cfg)

    def param_shardings(self, params):
        return jax.tree.map(self.named, self.param_specs(params),
                            is_leaf=lambda x: isinstance(x, P))

    def place_params(self, params):
        """Commit a concrete parameter pytree to the mesh per the rule."""
        return jax.device_put(params, self.param_shardings(params))

    def shard_group(self, body, template_batches, n_clients: int,
                    out_ndims=(2, 2)):
        """Wrap a client-group function in ``shard_map`` over this mesh.

        ``body(params, keys, batches) -> (deltas [K, n], gs [K, T, ...])``
        must process its clients with ``jax.lax.map`` (unbatched slices) —
        under ``shard_map`` each device then runs the *identical*
        per-client program on its slice of the client axis, which is what
        makes the sharded round bit-exact: no GSPMD cost-model choices, no
        batch-width-dependent matmul kernels (DESIGN.md §9).

        Parameters enter with ``in_specs=P()`` — the explicit ZeRO-3
        gather: stored FSDP-sharded between rounds, all-gathered once at
        round-body entry, amortized over the T local steps.  ``keys``
        replicate.  The client axis of ``batches`` and of both outputs
        shards over :attr:`batch_axes` when ``n_clients`` divides; a
        ragged fleet replicates (every device runs all clients).

        ``template_batches``: the stacked batch dict (for leaf ranks);
        ``out_ndims``: ranks of the (deltas, gs) outputs."""
        from jax.experimental.shard_map import shard_map
        k_spec = self.batch_axes if n_clients % self.dp == 0 else None

        def kspec(ndim):
            return P(k_spec, *([None] * (ndim - 1)))

        return shard_map(
            body, mesh=self.mesh,
            in_specs=(P(), P(None),
                      {k: kspec(v.ndim)
                       for k, v in template_batches.items()}),
            out_specs=tuple(kspec(nd) for nd in out_ndims),
            check_rep=False)

    def compute_view(self, params):
        """The in-graph view of the (sharded-at-rest) parameters that the
        vmapped client group computes with.

        ``"fsdp"``/``"replicate"``: constrain to replicated — ZeRO-3
        semantics, one all-gather of the weights per round body, amortized
        over the T local steps and 2T forwards.  This is what makes the
        sharded round *bit-exact*: left to its own cost model, GSPMD may
        instead split a matmul over an FSDP-sharded contraction dim
        (partial sums + all-reduce), which reorders float accumulation
        (DESIGN.md §9).  ``"tp"``: constrain to the Megatron specs —
        compute stays tensor-parallel (allclose-level parity only)."""
        if self.rule == "tp":
            specs = self.param_specs(params)
        else:
            specs = jax.tree.map(lambda l: P(*([None] * l.ndim)), params)
        return jax.tree.map(
            lambda a, s: jax.lax.with_sharding_constraint(a, self.named(s)),
            params, specs)

    def constrain_params_fn(self):
        """``params -> params`` re-applying the plan's weight shardings.

        For the non-vmapped production steps (``fl_step.make_fl_train_step``
        / ``make_fl_train_loop``): the sparse scatter erases GSPMD's weight
        shardings, so the step re-constrains after every perturb/update
        (DESIGN.md §perf)."""
        def cp(params):
            return jax.tree.map(
                lambda a, s: jax.lax.with_sharding_constraint(
                    a, self.named(s)),
                params, self.param_specs(params))
        return cp

    # -- batch placement -----------------------------------------------------
    def client_batch_spec(self, n_clients: int, ndim: int) -> P:
        """Spec for one stacked client-batch leaf ``[K, T, b, ...]``.

        The client axis ``K`` shards over :attr:`batch_axes` when
        divisible; otherwise the batch replicates (a ragged client fleet
        still runs, just without the data-parallel split)."""
        k_spec = self.batch_axes if n_clients % self.dp == 0 else None
        return P(k_spec, *([None] * (ndim - 1)))

    def place_client_batches(self, batches, n_clients: int):
        """Commit a stacked batch dict (leaves ``[K, T, b, ...]``) to the
        mesh, client axis over :attr:`batch_axes`."""
        return {k: jax.device_put(
                    v, self.named(self.client_batch_spec(n_clients, v.ndim)))
                for k, v in batches.items()}

    def place_replicated(self, x):
        """Commit an array (PRNG keys, scalars) replicated on the mesh."""
        return jax.device_put(x, self.replicated())

    # -- model context -------------------------------------------------------
    def shard_ctx(self, base_ctx):
        """A ``ShardCtx`` carrying this plan's mesh + batch axes, so model
        forwards apply their activation sharding constraints and
        ``resolve_attn_backend`` sees the sharded-mesh layout.

        Under ``"fsdp"``/``"replicate"`` the ``'model'`` axis is folded
        into ``batch_axes`` (``ShardCtx.attn_head_spec`` then emits no
        tensor-parallel activation specs), so no constraint ever splits a
        contraction dim — the bit-exactness invariant of DESIGN.md §9."""
        return dataclasses.replace(base_ctx, mesh=self.mesh,
                                   batch_axes=self.batch_axes)


def make_fl_plan(mesh_cfg: Optional[MeshConfig] = None, *,
                 spec: Optional[str] = None,
                 rule: str = "fsdp") -> FLShardPlan:
    """Build an :class:`FLShardPlan` from a :class:`MeshConfig` or a CLI
    mesh spec string (``"2x2"``; see ``launch/mesh.parse_mesh_spec``).

    The process must already have enough devices — on CPU hosts that means
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` was exported
    before the first jax import."""
    from repro.launch.mesh import make_mesh_from_config, parse_mesh_spec
    if (mesh_cfg is None) == (spec is None):
        raise ValueError("pass exactly one of mesh_cfg= or spec=")
    if mesh_cfg is None:
        mesh_cfg = parse_mesh_spec(spec)
    return FLShardPlan(make_mesh_from_config(mesh_cfg), mesh_cfg, rule)
