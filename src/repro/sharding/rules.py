"""Logical -> mesh sharding rules for every architecture.

Megatron-style tensor parallelism over the 'model' axis:
  * column-parallel: QKV projections, MLP up/gate, router-free expert stacks
  * row-parallel: attention out-proj, MLP down
  * expert-parallel: MoE expert stacks sharded on the expert dim
  * vocab-parallel embeddings / LM head
Batch (= FL client) dims shard over ('pod','data'); the long_500k decode
shape (B=1) shards KV caches over the *sequence* dim instead.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np

from repro.configs.base import InputShape, MeshConfig, ModelConfig

P = jax.sharding.PartitionSpec

# leaf name -> how to shard (see _leaf_spec)
_COL = {"wq", "wk", "wv", "bq", "bk", "bv", "w1", "w3", "sw1", "sw3",
        "in_proj", "up_proj", "w_gates", "b_gates", "dt_proj", "conv_w",
        "lora_qb", "lora_vb"}
_ROW = {"wo", "w2", "sw2", "down_proj", "out_proj"}
_EDIM1 = {"conv_b", "dt_bias", "A_log", "D"}  # mamba per-E leaves: dim after n


def _div(n: int, by: int) -> bool:
    return n % by == 0


def _leaf_spec(path: str, shape: Tuple[int, ...], tp: int):
    name = path.rsplit("'", 2)[-2] if "'" in path else path
    nd = len(shape)
    if name == "embed":
        return P("model", None) if _div(shape[0], tp) else P(None, None)
    if name == "lm_head":
        return P(None, "model") if _div(shape[1], tp) else P(None, None)
    if name in ("w1", "w2", "w3") and nd == 4:  # MoE expert stacks [n,E,D,F]
        if _div(shape[1], tp):
            return P(None, "model", None, None)
        return P(*([None] * nd))
    if name in _COL and nd >= 2:
        if _div(shape[-1], tp):
            return P(*([None] * (nd - 1)), "model")
    if name in _ROW and nd >= 2:
        if _div(shape[-2], tp):
            return P(*([None] * (nd - 2)), "model", None)
    if name in _EDIM1 and nd >= 2:
        if _div(shape[1], tp):
            return P(None, "model", *([None] * (nd - 2)))
    return P(*([None] * nd))


_FSDP_THRESHOLD = 64 * 1024 * 1024  # bytes per (tp-sharded) leaf shard


def _add_fsdp(spec: P, shape: Tuple[int, ...], mesh_cfg: MeshConfig,
              itemsize: int = 2):
    """ZeRO-3-style second sharding axis: if a leaf's per-shard size still
    exceeds the threshold after tensor parallelism, also shard the largest
    free dim over the batch axes (GSPMD all-gathers it per scan iteration)."""
    dp = mesh_cfg.data * mesh_cfg.pods
    used = {a for s in spec if s for a in ((s,) if isinstance(s, str) else s)}
    per_shard = np.prod(shape) * itemsize
    for s, dim in zip(spec, shape):
        if s is not None:
            per_shard //= mesh_cfg.model if s == "model" else 1
    if per_shard <= _FSDP_THRESHOLD or "data" in used:
        return spec
    dims = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in dims:
        if spec[i] is None and shape[i] % dp == 0 and shape[i] >= dp:
            new = list(spec)
            new[i] = mesh_cfg.batch_axes if mesh_cfg.pods > 1 else "data"
            return P(*new)
    return spec


def param_specs(cfg: ModelConfig, abstract_params, mesh_cfg: MeshConfig,
                train: bool = True):
    """PartitionSpec pytree matching the parameter tree.

    ``train=False`` (prefill/decode) skips the ZeRO-3 second axis: inference
    re-reads weights every step, so FSDP would all-gather large leaves per
    token (§Perf iteration 2 removed a per-step 136 MB lm_head gather)."""
    tp = mesh_cfg.model
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = []
    for p, l in flat:
        s = _leaf_spec(jax.tree_util.keystr(p), l.shape, tp)
        if train:
            s = _add_fsdp(s, l.shape, mesh_cfg)
        specs.append(s)
    return jax.tree_util.tree_unflatten(treedef, specs)


def fsdp_only_specs(cfg: ModelConfig, abstract_params, mesh_cfg: MeshConfig):
    """Pure-DP + FSDP sharding for the ZO step (beyond-paper, §Perf pair 2).

    ZO fine-tuning runs *no backward pass*, so Megatron tensor parallelism
    only buys per-layer activation all-reduces it doesn't need.  Instead:
    every device is a data shard (the FL-client axis spans the whole mesh)
    and each weight leaf is sharded over all devices on its largest
    divisible dim; GSPMD all-gathers one period's weights per scan step.
    Collective per forward = total weight bytes (vs 2 x activation psums
    per *layer* under TP)."""
    axes = tuple(mesh_cfg.axis_names)  # e.g. ("data", "model")
    n = mesh_cfg.n_devices
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    specs = []
    for p, l in flat:
        spec = [None] * len(l.shape)
        dims = sorted(range(len(l.shape)), key=lambda i: -l.shape[i])
        for i in dims:
            if l.shape[i] % n == 0:
                spec[i] = axes
                break
        specs.append(P(*spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


def token_spec(shape: InputShape, mesh_cfg: MeshConfig):
    ba = mesh_cfg.batch_axes
    dp = mesh_cfg.data * mesh_cfg.pods
    if shape.global_batch % dp:
        return P(None, None)
    return P(ba, None)


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh_cfg: MeshConfig):
    """Specs for the input batch dict (same keys as model.input_specs)."""
    ba = mesh_cfg.batch_axes
    dp = mesh_cfg.data * mesh_cfg.pods
    b_ok = shape.global_batch % dp == 0
    bspec = ba if b_ok else None
    out = {}
    if shape.kind == "decode":
        out["token"] = P(bspec)
    else:
        out["tokens"] = P(bspec, None)
        if cfg.frontend == "audio_stub":
            out["audio_embeds"] = P(bspec, None, None)
        elif cfg.frontend == "vision_stub":
            out["patch_embeds"] = P(bspec, None, None)
    return out


def _cache_leaf_spec(path: str, shape: Tuple[int, ...], mesh_cfg: MeshConfig,
                     seq_shard: bool):
    """Cache leaves: [n, B, ...] stacked over periods on dim 0."""
    ba = mesh_cfg.batch_axes
    dp = mesh_cfg.data * mesh_cfg.pods
    tp = mesh_cfg.model
    name = path.rsplit("'", 2)[-2] if "'" in path else path
    nd = len(shape)
    if name == "pos":
        return P()
    b_ok = nd >= 2 and shape[1] % dp == 0 and not seq_shard
    bspec = ba if b_ok else None
    if name in ("k", "v", "ck", "cv"):  # [n, B, W, KV, hd]
        # Preference order: KV heads over 'model' -> sequence over 'model'
        # -> head_dim as last resort.  Sharding head_dim makes the score
        # matmul's contraction dim sharded and GSPMD all-gathers the whole
        # cache per layer (§Perf iteration 1: 4.76s -> ms of collective).
        hspec = "model" if shape[3] % tp == 0 else None
        sspec = None
        if seq_shard and shape[2] % dp == 0:
            # B=1 long-context: sequence over batch axes (+ model if free)
            if hspec is None and shape[2] % (dp * tp) == 0:
                sspec = tuple(ba) + ("model",)
            else:
                sspec = ba
        elif hspec is None and shape[2] % tp == 0:
            sspec = "model"
        dspec = ("model" if (hspec is None and sspec is None
                             and shape[4] % tp == 0) else None)
        return P(None, bspec, sspec, hspec, dspec)
    if name == "conv":      # [n, B, K-1, E]
        espec = "model" if shape[3] % tp == 0 else None
        return P(None, bspec, None, espec)
    if name == "state":     # [n, B, E, N]
        espec = "model" if shape[2] % tp == 0 else None
        return P(None, bspec, espec, None)
    if name in ("c", "n", "h", "m") and nd == 3:  # slstm [n, B, E]
        espec = "model" if shape[2] % tp == 0 else None
        return P(None, bspec, espec)
    if name in ("C",):      # mlstm [n, B, H, dh, dh]
        return P(None, bspec, *([None] * (nd - 2)))
    return P(None, bspec, *([None] * max(nd - 2, 0)))


def cache_specs(cfg: ModelConfig, abstract_cache, shape: InputShape,
                mesh_cfg: MeshConfig):
    dp = mesh_cfg.data * mesh_cfg.pods
    seq_shard = shape.global_batch % dp != 0  # B=1 long-context decode
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_cache)
    specs = [_cache_leaf_spec(jax.tree_util.keystr(p), l.shape, mesh_cfg,
                              seq_shard) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def mask_specs(abstract_idx_tree, mesh_cfg: MeshConfig, replicate=True):
    """Sparse-mask index arrays: replicated baseline (each device holds the
    full coordinate list); the shard-aligned layout is a perf iteration."""
    return jax.tree.map(lambda l: P(None), abstract_idx_tree)
