"""Kernel-dispatch layer: flat-vector backing for the sparse-ZO hot path.

The MEERKAT inner loop perturbs and updates the parameter vector at every
step.  Written over pytrees (``space.add``), each phase is a chain of
per-leaf scatters — three full HBM round-trips per step.  The fused Pallas
kernels (``kernels/zo_update.py``) do each phase in a single pass, but they
operate on flat ``[N]`` vectors in the (R, 128) tile layout.

:class:`FlatBacking` bridges the two worlds for a (space, param-template)
pair.  It caches the static layout (leaf shapes / dtypes / offsets) plus the
dense 0/1 mask and the int32 global scatter indices that map the space's
``[n]`` sparse value vectors into the flat ``[N]`` coordinate system:

* ``flatten(params)``   pytree -> ``[N]`` (leaf-concatenation order)
* ``unflatten(flat)``   ``[N]`` -> pytree (casts back to each leaf dtype)
* ``expand(vec)``       ``[n]`` sparse values -> dense ``[N]`` f32
* ``restrict(flat)``    dense ``[N]`` -> ``[n]`` values at the space coords

Backend selection (``resolve_backend``):

* ``"pallas"`` — flat route through ``zo_dual_perturb_flat`` /
  ``zo_fused_update_flat``.  On TPU the kernels run compiled; on CPU (tests,
  simulations) they run in interpret mode (``kernels/ops.py`` flips
  automatically).
* ``"ref"``    — the original pytree ``space.add`` route (the reference
  semantics, and the only correct choice on the sharded production mesh:
  a flat reshape of a tensor-parallel weight is not representable for
  GSPMD, so the flat route would all-gather every weight — DESIGN.md §perf).
* ``"auto"``   — pallas when the layout supports it (uniform leaf dtype,
  N < 2**31 so int32 indices are exact, non-empty space) and the step is
  not sharding-constrained; ref otherwise.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.zo_update import LANE, SUB

_INT32_MAX = 2**31 - 1
_TILE = SUB * LANE  # (8, 128) sublane tile quantum of the fused kernels
BACKENDS = ("auto", "pallas", "ref")


def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


class FlatBacking:
    """Flat [N] view of a space over a parameter template (see module doc)."""

    def __init__(self, space, template):
        self.space = space
        leaves, self.treedef = jax.tree_util.tree_flatten(template)
        if not leaves:
            raise ValueError("empty parameter template")
        self.shapes = [tuple(l.shape) for l in leaves]
        self.dtypes = [jnp.dtype(l.dtype) for l in leaves]
        self.sizes = [int(np.prod(s)) if s else 1 for s in self.shapes]
        self.offsets = np.concatenate([[0], np.cumsum(self.sizes)]).astype(
            np.int64)
        self.n_flat = int(self.offsets[-1])
        # flat vectors are carried at the kernel tile quantum so the (R, 128)
        # reshape inside ops.py never has to pad-copy any operand
        self.n_pad = -(-self.n_flat // _TILE) * _TILE
        self.dtype = self.dtypes[0] if len(set(self.dtypes)) == 1 else None
        # identity: the space covers every coordinate *in storage order*
        # (DenseSpace; or a mask selecting everything) — skip the scatter.
        # Spaces that guarantee this structurally say so (identity_layout),
        # costing nothing.  A merely full-coverage mask is verified against
        # arange — the index contract allows any per-leaf order, and a
        # permuted full mask must take the scatter path.
        self.identity = bool(getattr(space, "identity_layout",
                                     lambda: False)()
                             and space.n == self.n_flat)
        self._idx_leaves = None
        self._idx_concrete = True
        if not self.identity:
            idx_leaves = space.leaf_index_arrays(template)
            concrete = not any(_is_tracer(i) for i in idx_leaves)
            if space.n == self.n_flat and concrete:
                self.identity = all(
                    np.array_equal(np.asarray(i), np.arange(s))
                    for i, s in zip(idx_leaves, self.sizes))
            if not self.identity:
                self._idx_leaves = idx_leaves
                self._idx_concrete = concrete
        self._global_index = None
        self._mask = None

    @property
    def global_index(self):
        """[n] int32 flat positions of the space coords (None if identity).

        Built lazily — the ref backend and huge layouts never pay for it.
        Concrete index trees build in numpy and cache (jnp constructors
        inside a jit trace yield tracers, which must never end up in the
        per-space cache); traced trees (dry-run) rebuild in-graph per use."""
        if self.identity:
            return None
        if self.n_flat > _INT32_MAX:
            raise ValueError(
                f"flat layout of {self.n_flat} coords exceeds int32 indexing;"
                " use backend='ref'")
        if self._global_index is not None:
            return self._global_index
        if self._idx_concrete:
            gidx = np.concatenate(
                [np.asarray(i, np.int64) + off
                 for i, off in zip(self._idx_leaves, self.offsets[:-1])])
            self._global_index = gidx.astype(np.int32)
            return self._global_index
        return jnp.concatenate(  # traced: per-use, uncached
            [jnp.asarray(i, jnp.int32) + jnp.int32(off)
             for i, off in zip(self._idx_leaves, self.offsets[:-1])])

    @property
    def mask(self):
        """Dense [n_pad] f32 0/1 mask (diagnostics / 3-operand kernels).

        The hot paths run the pre-masked kernel variants and never read it;
        built lazily like :attr:`global_index`."""
        if self._mask is not None:
            return self._mask
        if self.identity:
            mask = np.zeros((self.n_pad,), np.float32)
            mask[:self.n_flat] = 1.0
            self._mask = mask
            return mask
        gidx = self.global_index
        if self._idx_concrete:
            mask = np.zeros((self.n_pad,), np.float32)
            mask[gidx] = 1.0
            self._mask = mask
            return mask
        return jnp.zeros((self.n_pad,), jnp.float32).at[gidx].set(1.0)

    @property
    def supported(self) -> bool:
        """Whether the flat kernel route is usable for this layout."""
        return (self.dtype is not None and self.n_flat <= _INT32_MAX
                and self.space.n > 0)

    @property
    def cacheable(self) -> bool:
        return self._idx_concrete

    def flatten(self, params):
        """Concatenate raveled leaves -> [n_pad] (uniform dtype, or f32).

        The tail beyond ``n_flat`` is zeros; every kernel operand therefore
        arrives already in the (R, 128)-tileable length."""
        leaves = jax.tree_util.tree_leaves(params)
        dt = self.dtype or jnp.float32
        segs = [l.reshape(-1).astype(dt) for l in leaves]
        if self.n_pad > self.n_flat:
            segs.append(jnp.zeros((self.n_pad - self.n_flat,), dt))
        return jnp.concatenate(segs)

    def unflatten(self, flat):
        """Split a flat [n_pad] (or [N]) vector back into the pytree."""
        out = [flat[int(o):int(o) + s].reshape(sh).astype(dt)
               for o, s, sh, dt in zip(self.offsets[:-1], self.sizes,
                                       self.shapes, self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, out)

    def expand(self, vec):
        """Sparse [n] values -> dense [n_pad] f32 (zeros elsewhere)."""
        if self.identity:
            v = vec.astype(jnp.float32)
            if self.n_pad > self.n_flat:
                v = jnp.concatenate([v, jnp.zeros((self.n_pad - self.n_flat,),
                                                  jnp.float32)])
            return v
        return jnp.zeros((self.n_pad,), jnp.float32).at[
            self.global_index].set(vec.astype(jnp.float32))

    def restrict(self, flat):
        """Dense [n_pad] (or [N]) -> the [n] values at the space coords."""
        if self.identity:
            return flat[:self.n_flat].astype(jnp.float32)
        return flat[self.global_index].astype(jnp.float32)

    def scatter_into(self, buf, vec):
        """Overwrite the space's coordinates of a dense [n_pad] f32 buffer
        with ``vec`` [n].  Equivalent to :meth:`expand` whenever ``buf`` is
        zero off the coordinates (the coordinate set is static, so every
        overwrite leaves the off-coordinate zeros untouched) — without
        re-materializing the n_pad zero vector.  The scanned hot loops
        carry one dense z buffer and refresh it in place each step, saving
        a full-vector write per step."""
        v = vec.astype(jnp.float32)
        if self.identity:
            return jax.lax.dynamic_update_slice(buf, v, (0,))
        return buf.at[self.global_index].set(v)


def _layout_key(template):
    leaves, treedef = jax.tree_util.tree_flatten(template)
    return (treedef, tuple((tuple(l.shape), str(jnp.dtype(l.dtype)))
                           for l in leaves))


def get_backing(space, template) -> FlatBacking:
    """FlatBacking for (space, template), cached on the space instance.

    The cached arrays (mask, global indices) derive only from the space's
    index tree and the template's *shapes* — never from parameter values —
    so the cache is safe to reuse across jit traces.  When the index tree
    itself is traced (the dry-run's abstract masks) nothing is cached.
    """
    key = _layout_key(template)
    cached = getattr(space, "_flat_backing", None)
    if cached is not None and cached[0] == key:
        return cached[1]
    backing = FlatBacking(space, template)
    if backing.cacheable:
        space._flat_backing = (key, backing)
    return backing


# auto stays on the pytree route when the flat path would materialize more
# dense state than this, *summed over vmapped clients* (the T>1 loops scan
# a dense [n_pad] f32 delta per client, T=1 steps hold a handful of dense
# transients; ref touches only sparse [n] vectors and in-place scatters).
# The budget is platform-scaled: CPU simulations get 256 MiB, a real TPU
# (where the flat route is the point) gets 8 GiB of HBM headroom.
# Explicit backend="pallas" always overrides.
DENSE_CARRY_AUTO_BYTES = 256 * 1024 * 1024
DENSE_CARRY_AUTO_BYTES_TPU = 8 * 1024 * 1024 * 1024


def _carry_budget() -> int:
    return (DENSE_CARRY_AUTO_BYTES_TPU
            if jax.default_backend() == "tpu" else DENSE_CARRY_AUTO_BYTES)


def resolve_backend(backend: Optional[str], backing: FlatBacking, *,
                    sharded: bool = False, dense_carry: int = 1) -> str:
    """Map a requested backend ('auto'/None included) to 'pallas' | 'ref'.

    ``dense_carry`` is the number of concurrent dense [n_pad] f32 state
    vectors the pallas route implies — one per vmapped client in
    make_local_run / make_fl_round_step, one for a single T=1 step.  Auto
    requires their total to fit the platform carry budget so huge
    unsharded models don't trade sparse [n] traffic for an OOM."""
    backend = backend or "auto"
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if backend == "auto":
        if sharded or not backing.supported:
            return "ref"
        if 4 * backing.n_pad * max(1, dense_carry) > _carry_budget():
            return "ref"
        return "pallas"
    return backend
