"""Server seed ladder (paper Alg. 2: the server initializes a seed list
``{s_r^1..s_r^T}`` per round; clients and server derive identical Gaussian
perturbations from it — the basis of the virtual path)."""
from __future__ import annotations

import jax


def round_keys(root_seed: int, rnd: int, T: int):
    """The T per-step PRNG keys for round ``rnd`` (shared by all clients).

    ``rnd`` may be negative (the VP calibration phase uses round -1); it is
    mapped into uint32 range for fold_in."""
    k = jax.random.fold_in(jax.random.key(root_seed), rnd & 0xFFFFFFFF)
    return jax.random.split(k, T)


def step_key(root_seed: int, rnd: int, t: int):
    return round_keys(root_seed, rnd, t + 1)[t]
