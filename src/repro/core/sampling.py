"""Seeded per-round client sampling for fleet-scale federated rounds
(DESIGN.md §12).

With K in the thousands, running every client every round is neither
realistic nor necessary: each round the server draws a fixed-size cohort
``m = max(1, round(frac * K))`` — uniformly, or weighted by client data
size — and only the cohort runs local steps, uploads scalars, and
receives downlink.  Unsampled clients get an explicit GradIP gap
(``None``), mirroring the dropout bookkeeping.

Determinism contract: the sampler is a *stateful* seeded
``numpy.random.Generator`` advancing exactly one draw per round, in
lockstep with the server's round counter (``cohort(r)`` asserts the
lockstep).  Its full bit-generator state is serialized into server
checkpoints (``checkpoint/state.py``), so a resumed server re-draws the
killed round's cohort identically — the sampled analogue of the seed
ladder's bit-exact-replay invariant.  Cohorts have *fixed size* and are
returned sorted, so every round reuses one compiled group program (the
cohort is data, not shape).
"""
from __future__ import annotations

import json
from typing import Optional, Sequence

import numpy as np


class ClientSampler:
    """Per-round cohort draws over a fixed client-id universe.

    Args:
      cids: the fleet's client ids (deduplicated, sorted internally).
      frac: participation fraction; cohort size ``max(1, round(frac*K))``.
      m: explicit cohort size (overrides ``frac``).
      weights: optional per-client sampling weights aligned with the
        *sorted* cids (e.g. client dataset sizes); drawn without
        replacement, so at least ``m`` weights must be positive.
      seed: generator seed (conventionally ``fl.seed``).
    """

    def __init__(self, cids: Sequence[int], *, frac: Optional[float] = None,
                 m: Optional[int] = None,
                 weights: Optional[Sequence[float]] = None, seed: int = 0):
        self.cids = tuple(sorted(int(c) for c in cids))
        if len(set(self.cids)) != len(self.cids):
            raise ValueError(f"duplicate client ids: {cids}")
        k = len(self.cids)
        if m is None:
            if frac is None:
                raise ValueError("need frac or m")
            if not 0.0 < frac <= 1.0:
                raise ValueError(f"frac must be in (0, 1], got {frac}")
            m = max(1, int(round(frac * k)))
        if not 1 <= m <= k:
            raise ValueError(f"cohort size m={m} outside [1, {k}]")
        self.m = int(m)
        if weights is not None:
            w = np.asarray(weights, np.float64)
            if w.shape != (k,):
                raise ValueError(f"weights shape {w.shape} != ({k},)")
            if (w < 0).any() or w.sum() <= 0:
                raise ValueError("weights must be >= 0 with positive sum")
            if int((w > 0).sum()) < self.m:
                raise ValueError(
                    f"only {int((w > 0).sum())} clients have positive "
                    f"weight but the cohort needs {self.m} (sampling is "
                    "without replacement)")
            self._p = w / w.sum()
        else:
            self._p = None
        self.seed = int(seed)
        self.rounds_sampled = 0
        self._rng = np.random.default_rng(self.seed)

    @property
    def weighted(self) -> bool:
        return self._p is not None

    def cohort(self, rnd: Optional[int] = None) -> tuple:
        """Draw the next round's cohort: sorted tuple of ``m`` distinct
        cids.  ``rnd`` (the server's round counter) asserts the lockstep
        — one draw per round, in order — that makes resumed draws land
        on the same rng state as the uninterrupted run."""
        if rnd is not None and int(rnd) != self.rounds_sampled:
            raise ValueError(
                f"out-of-order cohort draw: round {rnd} but the sampler "
                f"has drawn {self.rounds_sampled} rounds (one draw per "
                "round, in round order)")
        idx = self._rng.choice(len(self.cids), size=self.m, replace=False,
                               p=self._p)
        self.rounds_sampled += 1
        return tuple(sorted(self.cids[int(i)] for i in idx))

    # -- checkpoint plumbing (msgpack-safe: PCG64's 128-bit state ints
    # travel as a JSON string — json handles bignums, msgpack does not) --
    def state_dict(self) -> dict:
        return {"cids": list(self.cids), "m": self.m,
                "weighted": self.weighted, "seed": self.seed,
                "rounds_sampled": int(self.rounds_sampled),
                "rng": json.dumps(self._rng.bit_generator.state)}

    def load_state(self, d: dict) -> None:
        """Restore a :meth:`state_dict` snapshot (identity fields must
        match — the rng state only transfers onto the same universe)."""
        for field, have in (("cids", list(self.cids)), ("m", self.m),
                            ("weighted", self.weighted)):
            if d.get(field) != have:
                raise ValueError(
                    f"sampler state mismatch at {field!r}: checkpoint "
                    f"{d.get(field)!r} vs sampler {have!r}")
        self.rounds_sampled = int(d["rounds_sampled"])
        self._rng.bit_generator.state = json.loads(d["rng"])
