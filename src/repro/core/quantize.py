"""Quantized uplink for the ZO projected-gradient scalars (DESIGN.md §12).

The fleet-scale uplink compresses each client's [T] (or [T, K]) scalar
upload to ``bits``-bit integer codes plus one shared exponent per chunk.
Scales are **powers of two** chosen per chunk:

    e = min integer with  qmax * 2^e >= max|x|,   qmax = 2^(bits-1) - 1
    code = round(x * 2^-e)  (stochastic or nearest), clipped to [-qmax, qmax]
    x_hat = code * 2^e

Power-of-two scales make every op in the pipeline *exact* in f32
(``ldexp`` only shifts the exponent), which buys two invariants the
virtual-path replay needs:

* **Idempotence** — ``decode(encode(x_hat))`` is bit-identical to
  ``x_hat`` for any already-on-grid ``x_hat``: its re-encoded exponent
  ``e'`` is <= ``e`` (the grid only refines), the rescaled codes are
  integers with no fractional part, and both rounding modes pass
  integers through unchanged.  So the server's deterministic (nearest)
  re-encode of a client's applied value reproduces the client's value
  exactly — the **exact-replay invariant**: the virtual path is
  bit-reconstructible from the compressed wire payload alone.
* **Error bound** — the grid spacing ``2^e`` satisfies
  ``2^e <= 2 * max|x| / qmax`` (minimality of ``e``), so the roundtrip
  error is at most one grid step (half a step for nearest rounding).

Stochastic rounding (``floor(q) + Bernoulli(frac(q))``) keeps the
quantizer *unbiased* — ``E[x_hat] = x`` — so the aggregated mean over a
cohort converges to the unquantized mean.  The client-side jax
roundtrip draws its Bernoulli noise from the step key folded with
:data:`QUANT_FOLD`, a stream disjoint from z sampling — pure function
of ``(fl.seed, round, step)``, so quantized runs resume bit-exactly.

The jax in-loop path (:func:`quantize_roundtrip`,
:class:`QuantSpec.apply`) is per-scalar (chunk=1): the local T-step scan
applies each quantized g_t before computing g_{t+1}, so no cross-step
chunk is possible.  The host :class:`IntCodec` supports ``chunk > 1``
for batch payloads and property tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

# largest integer code magnitude per bit width (symmetric signed grid)
QMAX = {4: 7, 8: 127}
# f32 exponent clip — keeps every ldexp finite and exact
E_MIN, E_MAX = -127, 127
# salt folded into the per-step/per-direction PRNG key for the rounding
# draw (disjoint from the z-sampling stream derived from the same key)
QUANT_FOLD = 0x51AD


def pow2_exponent(amax: np.ndarray, bits: int) -> np.ndarray:
    """Smallest ``e`` (int32, clipped to [E_MIN, E_MAX]) with
    ``qmax * 2^e >= amax``, computed with exact f32 ops (frexp/ldexp)
    so host numpy and jax agree bit-for-bit."""
    qmax = np.float32(QMAX[bits])
    amax = np.asarray(amax, np.float32)
    _, e_frexp = np.frexp(amax)
    e0 = e_frexp.astype(np.int32) - (bits - 1)
    e = np.where(np.ldexp(qmax, e0) >= amax, e0, e0 + 1)
    return np.clip(e, E_MIN, E_MAX).astype(np.int32)


def wire_nbytes(n: int, bits: int, chunk: int = 1) -> int:
    """Serialized size of an n-scalar payload: packed codes (two int4
    codes per byte) + one exponent byte per chunk."""
    return (n * bits + 7) // 8 + math.ceil(n / chunk)


def pack_codes(codes: np.ndarray, bits: int) -> bytes:
    """Serialize int codes: int8 verbatim; int4 as offset nibble pairs."""
    codes = np.asarray(codes, np.int8).ravel()
    if bits == 8:
        return codes.tobytes()
    u = (codes.astype(np.int16) + 8).astype(np.uint8)  # [-7, 7] -> [1, 15]
    if u.size % 2:
        u = np.concatenate([u, np.zeros((1,), np.uint8)])
    return (u[0::2] | (u[1::2] << 4)).tobytes()


def unpack_codes(raw: bytes, bits: int, n: int) -> np.ndarray:
    """Inverse of :func:`pack_codes` — int8 [n] codes."""
    if bits == 8:
        return np.frombuffer(raw, np.int8, count=n).copy()
    b = np.frombuffer(raw, np.uint8)
    u = np.stack([b & 0x0F, b >> 4], axis=1).ravel()[:n]
    return (u.astype(np.int16) - 8).astype(np.int8)


@dataclasses.dataclass(frozen=True)
class Wire:
    """One encoded payload: integer codes + per-chunk pow2 exponents."""
    codes: np.ndarray  # int8 [n], in [-qmax, qmax]
    exps: np.ndarray   # int8 [ceil(n / chunk)]
    shape: tuple
    bits: int
    chunk: int

    @property
    def n(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def nbytes(self) -> int:
        return wire_nbytes(self.n, self.bits, self.chunk)

    def tobytes(self) -> bytes:
        return pack_codes(self.codes, self.bits) + \
            np.asarray(self.exps, np.int8).tobytes()


@dataclasses.dataclass(frozen=True)
class FloatWire:
    """Identity-codec payload: raw f32 scalars (4 bytes each)."""
    values: np.ndarray

    @property
    def nbytes(self) -> int:
        return 4 * self.values.size

    def tobytes(self) -> bytes:
        return np.asarray(self.values, np.float32).tobytes()


def encode(x, bits: int, chunk: int = 1,
           rng: Optional[np.random.Generator] = None) -> Wire:
    """Host-side encode.  ``rng=None`` rounds to nearest (deterministic —
    what the server uses, exact on on-grid inputs); an ``rng`` draws the
    stochastic rounding noise."""
    x = np.asarray(x, np.float32)
    flat = x.ravel()
    n = flat.size
    n_chunks = math.ceil(n / chunk) if n else 0
    pad = n_chunks * chunk - n
    g = np.concatenate([flat, np.zeros((pad,), np.float32)])
    g = g.reshape(n_chunks, chunk)
    amax = np.abs(g).max(axis=1)
    e = pow2_exponent(amax, bits)
    q = np.ldexp(g, -e[:, None])  # exact: |q| <= qmax by choice of e
    if rng is None:
        qr = np.rint(q)
    else:
        lo = np.floor(q)
        qr = lo + (rng.random(q.shape) < (q - lo))
    qr = np.clip(qr, -QMAX[bits], QMAX[bits])
    return Wire(codes=qr.astype(np.int8).ravel()[:n],
                exps=e.astype(np.int8), shape=x.shape, bits=bits,
                chunk=chunk)


def decode(wire: Wire) -> np.ndarray:
    """Exact dequantize: ``code * 2^e`` per chunk, f32 [*wire.shape]."""
    n_chunks = wire.exps.size
    pad = n_chunks * wire.chunk - wire.n
    c = np.concatenate([wire.codes.astype(np.float32),
                        np.zeros((pad,), np.float32)])
    out = np.ldexp(c.reshape(n_chunks, wire.chunk),
                   wire.exps.astype(np.int32)[:, None])
    return out.ravel()[:wire.n].reshape(wire.shape).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """The client-side in-loop quantization recipe (jax route)."""
    bits: int
    stochastic: bool = True

    def apply(self, g, key):
        import jax
        return quantize_roundtrip(g, jax.random.fold_in(key, QUANT_FOLD),
                                  self.bits, self.stochastic)


def quantize_roundtrip(g, key, bits: int, stochastic: bool = True):
    """Jax-traceable per-scalar quantize + dequantize (chunk=1) — the
    value the client *applies* in its local update, and (being on-grid)
    the value the server's nearest re-encode reproduces bit-exactly.
    Same frexp/ldexp arithmetic as the host codec, so the nearest mode
    bit-matches :func:`encode`/:func:`decode` with ``chunk=1``."""
    import jax
    import jax.numpy as jnp
    g = jnp.asarray(g, jnp.float32)
    qmax = jnp.float32(QMAX[bits])
    amax = jnp.abs(g)
    _, e_frexp = jnp.frexp(amax)
    e0 = e_frexp.astype(jnp.int32) - (bits - 1)
    e = jnp.where(jnp.ldexp(qmax, e0) >= amax, e0, e0 + 1)
    e = jnp.clip(e, E_MIN, E_MAX)
    q = jnp.ldexp(g, -e)
    if stochastic:
        lo = jnp.floor(q)
        u = jax.random.uniform(key, q.shape, jnp.float32)
        qr = lo + (u < (q - lo)).astype(jnp.float32)
    else:
        qr = jnp.round(q)  # half-to-even, matching np.rint
    return jnp.ldexp(jnp.clip(qr, -qmax, qmax), e)


class IdentityCodec:
    """Pass-through codec: raw f32 scalars, 4 bytes each — today's dense
    protocol, and the bit-parity baseline for the quantized path."""
    spec = "none"
    bits = 32
    chunk = 1

    def encode(self, x, rng=None) -> FloatWire:
        return FloatWire(values=np.asarray(x, np.float32))

    def decode(self, wire: FloatWire) -> np.ndarray:
        return np.asarray(wire.values, np.float32)

    def nbytes(self, n: int) -> int:
        return 4 * int(n)

    def jax_spec(self) -> None:
        return None  # no in-loop quantization: trace today's program


class IntCodec:
    """Stochastic-rounding int8/int4 codec with per-chunk pow2 scales."""

    def __init__(self, bits: int, chunk: int = 1, stochastic: bool = True):
        if bits not in QMAX:
            raise ValueError(f"bits must be one of {sorted(QMAX)}, "
                             f"got {bits}")
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.bits = int(bits)
        self.chunk = int(chunk)
        self.stochastic = bool(stochastic)

    @property
    def spec(self) -> str:
        return f"int{self.bits}" + ("" if self.stochastic else "-nearest")

    def encode(self, x, rng: Optional[np.random.Generator] = None) -> Wire:
        return encode(x, self.bits, self.chunk, rng)

    def decode(self, wire: Wire) -> np.ndarray:
        return decode(wire)

    def nbytes(self, n: int) -> int:
        return wire_nbytes(int(n), self.bits, self.chunk)

    def jax_spec(self) -> QuantSpec:
        return QuantSpec(bits=self.bits, stochastic=self.stochastic)


def make_codec(spec: str):
    """Codec from a config string: ``none`` | ``int8`` | ``int4`` (+
    ``-nearest`` suffix for deterministic rounding)."""
    if spec in (None, "", "none"):
        return IdentityCodec()
    m = spec.removesuffix("-nearest")
    if m in ("int4", "int8"):
        return IntCodec(bits=int(m[3:]), stochastic=not
                        spec.endswith("-nearest"))
    raise ValueError(
        f"unknown quantize spec {spec!r}: want none|int8|int4"
        f"[-nearest]")
