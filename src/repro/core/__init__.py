"""MEERKAT core: the paper's contribution as composable JAX modules."""
from repro.core.dispatch import FlatBacking, get_backing, resolve_backend
from repro.core.fl_step import (make_fl_round_step, make_fl_train_loop,
                                make_fl_train_step)
from repro.core.gradip import (gradip_matrix, gradip_trajectory,
                               pretrain_gradient_vec)
from repro.core.masks import (abstract_mask, concrete_balanced_mask_like,
                              magnitude_mask, random_mask, sensitivity_mask,
                              sensitivity_scores)
from repro.core.quantize import (IdentityCodec, IntCodec, QuantSpec,
                                 make_codec, quantize_roundtrip)
from repro.core.sampling import ClientSampler
from repro.core.seeds import round_keys, step_key
from repro.core.server import Client, CommLog, FederatedZO
from repro.core.spaces import DenseSpace, LoRASpace, MaskedSpace
from repro.core.virtual_path import (aggregate, reconstruct_delta,
                                     reconstruct_from_wire,
                                     reconstruct_grad_vecs)
from repro.core.vpcs import VPCSResult, analyze_trajectory, select_clients
from repro.core.zo import local_step, make_local_run, projected_gradient
