"""Server-side virtual-path reconstruction (paper Alg. 2 step 2).

Because the server holds the round's seed list and receives each client's
projected gradients ``{g_k^t}``, it can regenerate every ``z_t`` and replay
the client's local trajectory *exactly* — without any client data.  Since
updates only touch the masked coordinates, the server tracks the sparse
value vector (delta) instead of full weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reconstruct_delta(space, keys, gs, lr: float, delta0=None):
    """Replay T local steps. gs: [T] (paper) or [T, K] (multi-direction ZO,
    K scalars per step); keys: [T]. Returns delta_T [n]."""
    if delta0 is None:
        delta0 = jnp.zeros((space.n,), jnp.float32)
    multi = gs.ndim == 2

    def step(delta, inp):
        key, g = inp
        if multi:
            dir_keys = jax.random.split(key, g.shape[0])
            zs = jax.vmap(space.sample_z)(dir_keys)
            upd = (g[:, None] * zs).mean(0)
        else:
            upd = g * space.sample_z(key)
        return delta - lr * upd, None

    delta_T, _ = jax.lax.scan(step, delta0, (keys, gs))
    return delta_T


def reconstruct_from_wire(space, keys, wire, codec, lr: float, delta0=None):
    """Replay a client's local trajectory directly from its **encoded
    uplink payload** — the fleet-scale server's entire per-client
    knowledge is (seed keys, wire bytes).

    In exact-replay mode (``core/quantize.py``: the client applies the
    wire-grid value at every local step, and on-grid values survive the
    codec bit-for-bit) ``codec.decode(wire)`` returns exactly the
    scalars the client's trajectory used, so this reconstruction is
    bit-identical to the client-side path even though only quantized
    bytes crossed the network."""
    return reconstruct_delta(space, keys,
                             jnp.asarray(codec.decode(wire), jnp.float32),
                             lr, delta0)


def reconstruct_grad_vecs(space, keys, gs):
    """The reconstructed ZO gradient vectors grad_hat_t = g_t * z_t.

    Returned as [T, n] (sparse-coordinate representation)."""

    def one(key, g):
        return g * space.sample_z(key)

    return jax.vmap(one)(keys, gs)


def aggregate(deltas, n_reporting=None):
    """FedAvg aggregation of reconstructed sparse client deltas: [K, n].

    ``n_reporting`` makes the normalization explicit for fault-tolerant
    rounds (FedMeZO-style: the mean is over whichever subset actually
    reported, so aggregation stays well-defined under client dropout).
    It defaults to ``deltas.shape[0]`` — plain FedAvg over the rows
    given — and must match it unless a caller deliberately rescales
    (e.g. normalizing by the full fleet to damp partial rounds).  A
    zero-survivor round has no rows to average: callers apply a zero
    update instead of calling this with an empty stack."""
    n = deltas.shape[0] if n_reporting is None else int(n_reporting)
    if n <= 0 or deltas.shape[0] == 0:
        raise ValueError(
            f"aggregate needs >= 1 reporting client (got rows="
            f"{deltas.shape[0]}, n_reporting={n_reporting}); zero-survivor "
            "rounds apply a zero update instead")
    return jnp.sum(deltas, axis=0) / n
