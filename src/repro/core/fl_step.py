"""Production federated-ZO train steps (what the dry-run lowers).

On the TPU mesh, FL clients are the (pod, data) shards.  With the shared
per-step seeds of Alg. 2/3, every client perturbs with the *same* z, so the
high-frequency (T=1) MEERKAT step is exactly:

    z  = N(0, I_n)                       (n = sparse coords, same everywhere)
    f+ = per-client loss at w + eps*z    (pure data-parallel forward)
    f- = per-client loss at w - eps*z
    g_k = (f+_k - f-_k) / 2 eps          (K scalars)
    w' = w - lr * mean_k(g_k) * z        (one sparse scatter)

The only cross-client collective is the scalar mean — the paper's 1000x
communication saving, visible structurally in the lowered HLO.

``make_fl_round_step`` is the T>1 variant (clients' deltas diverge within a
round, so clients are vmapped; used by simulations and small-scale runs).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_fl_train_step(per_example_loss: Callable, space, *, eps: float,
                       lr: float, n_clients: int, constrain_params=None):
    """T=1 high-frequency MEERKAT step (Alg. 3). Returns jittable fn
    (params, key, batch) -> (params', g_clients [K], metrics).

    ``constrain_params`` re-applies the parameter sharding after each sparse
    scatter — the flat-index scatter otherwise erases GSPMD's weight
    shardings and replicates all downstream matmuls (see DESIGN.md §perf)."""
    cp = constrain_params or (lambda p: p)

    def step(params, key, batch):
        z = space.sample_z(key)
        w_plus = cp(space.add(params, eps * z))
        l_plus = per_example_loss(w_plus, batch)          # [B_global]
        w_minus = cp(space.add(w_plus, (-2.0 * eps) * z))  # in-place chain
        l_minus = per_example_loss(w_minus, batch)
        g_clients = (l_plus - l_minus).reshape(n_clients, -1).mean(-1) \
            / (2.0 * eps)
        g = jnp.mean(g_clients)                           # scalar collective
        new_params = cp(space.add(w_minus, (eps - lr * g) * z))
        metrics = {"loss": jnp.mean(l_plus + l_minus) / 2.0, "g": g}
        return new_params, g_clients, metrics

    return step


def make_fl_round_step(loss_fn: Callable, space, *, eps: float, lr: float,
                       T: int):
    """Full MEERKAT round with T>1 local steps and vmapped clients.

    batches: pytree with leading [K, T, b, ...]; keys: [T] (shared across
    clients per Alg. 2).  Returns (params', gs [K, T])."""

    def client_run(params, keys, batches_c):
        def one(delta, inp):
            key, b = inp
            z = space.sample_z(key)
            lp = loss_fn(space.add(params, delta + eps * z), b)
            lm = loss_fn(space.add(params, delta - eps * z), b)
            g = (lp - lm) / (2.0 * eps)
            return delta - lr * g * z, g

        delta0 = jnp.zeros((space.n,), jnp.float32)
        return jax.lax.scan(one, delta0, (keys, batches_c))

    def round_step(params, keys, batches):
        deltas, gs = jax.vmap(client_run, in_axes=(None, None, 0))(
            params, keys, batches)
        agg = jnp.mean(deltas, axis=0)                    # [n] sparse collective
        return space.add(params, agg), gs

    return round_step
