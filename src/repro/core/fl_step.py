"""Production federated-ZO train steps (what the dry-run lowers).

On the TPU mesh, FL clients are the (pod, data) shards.  With the shared
per-step seeds of Alg. 2/3, every client perturbs with the *same* z, so the
high-frequency (T=1) MEERKAT step is exactly:

    z  = N(0, I_n)                       (n = sparse coords, same everywhere)
    f+ = per-client loss at w + eps*z    (pure data-parallel forward)
    f- = per-client loss at w - eps*z
    g_k = (f+_k - f-_k) / 2 eps          (K scalars)
    w' = w - lr * mean_k(g_k) * z        (one sparse scatter)

The only cross-client collective is the scalar mean — the paper's 1000x
communication saving, visible structurally in the lowered HLO.

Both step factories dispatch between the fused flat-vector Pallas route and
the pytree reference route (``core/dispatch.py``).  On the flat route the
perturb phase is one ``zo_dual_perturb_flat`` HBM pass producing both
perturbed copies and the weight update one ``zo_fused_update_flat`` pass —
versus three chained full-tree scatter passes on the reference route.

``make_fl_round_step`` is the T>1 variant (clients' deltas diverge within a
round, so clients are vmapped; used by simulations and small-scale runs).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.dispatch import get_backing, resolve_backend
from repro.kernels.ops import zo_dual_perturb_flat, zo_fused_update_flat


def make_fl_train_step(per_example_loss: Callable, space, *, eps: float,
                       lr: float, n_clients: int, constrain_params=None,
                       backend: Optional[str] = None):
    """T=1 high-frequency MEERKAT step (Alg. 3). Returns jittable fn
    (params, key, batch) -> (params', g_clients [K], metrics).

    ``constrain_params`` re-applies the parameter sharding after each sparse
    scatter — the flat-index scatter otherwise erases GSPMD's weight
    shardings and replicates all downstream matmuls (see DESIGN.md §perf).
    When it is set, backend="auto" resolves to the pytree route: flattening
    a tensor-parallel weight is not GSPMD-representable, so the fused flat
    kernels are reserved for the unsharded / FSDP-only regimes."""
    cp = constrain_params or (lambda p: p)

    def step(params, key, batch):
        backing = get_backing(space, params)
        be = resolve_backend(backend, backing,
                             sharded=constrain_params is not None)
        z = space.sample_z(key)
        if be == "ref":
            w_plus = cp(space.add(params, eps * z))
            l_plus = per_example_loss(w_plus, batch)          # [B_global]
            w_minus = cp(space.add(w_plus, (-2.0 * eps) * z))  # in-place chain
            l_minus = per_example_loss(w_minus, batch)
        else:
            w_flat = backing.flatten(params)
            z_flat = backing.expand(z)
            wp, wm = zo_dual_perturb_flat(w_flat, z_flat, None, eps)
            l_plus = per_example_loss(cp(backing.unflatten(wp)), batch)
            l_minus = per_example_loss(cp(backing.unflatten(wm)), batch)
        g_clients = (l_plus - l_minus).reshape(n_clients, -1).mean(-1) \
            / (2.0 * eps)
        g = jnp.mean(g_clients)                           # scalar collective
        if be == "ref":
            new_params = cp(space.add(w_minus, (eps - lr * g) * z))
        else:
            new_params = cp(backing.unflatten(zo_fused_update_flat(
                w_flat, z_flat, None, -lr * g)))
        metrics = {"loss": jnp.mean(l_plus + l_minus) / 2.0, "g": g}
        return new_params, g_clients, metrics

    return step


def make_fl_round_step(loss_fn: Callable, space, *, eps: float, lr: float,
                       T: int, backend: Optional[str] = None):
    """Full MEERKAT round with T>1 local steps and vmapped clients.

    batches: pytree with leading [K, T, b, ...]; keys: [T] (shared across
    clients per Alg. 2).  Returns (params', gs [K, T]).

    Flat route: the parameter vector is flattened once per round; each
    vmapped client carries its dense flat delta through the T-step scan with
    one fused dual-perturb + one fused update pass per step."""

    def client_run_ref(params, keys, batches_c):
        def one(delta, inp):
            key, b = inp
            z = space.sample_z(key)
            lp = loss_fn(space.add(params, delta + eps * z), b)
            lm = loss_fn(space.add(params, delta - eps * z), b)
            g = (lp - lm) / (2.0 * eps)
            return delta - lr * g * z, g

        delta0 = jnp.zeros((space.n,), jnp.float32)
        return jax.lax.scan(one, delta0, (keys, batches_c))

    def round_step(params, keys, batches):
        backing = get_backing(space, params)
        n_cl = jax.tree_util.tree_leaves(batches)[0].shape[0]
        if resolve_backend(backend, backing, dense_carry=n_cl) == "ref":
            deltas, gs = jax.vmap(client_run_ref, in_axes=(None, None, 0))(
                params, keys, batches)
        else:
            w_flat = backing.flatten(params)

            def client_run(batches_c):
                def one(delta_dense, inp):
                    key, b = inp
                    z_flat = backing.expand(space.sample_z(key))
                    wp, wm = zo_dual_perturb_flat(w_flat + delta_dense,
                                                  z_flat, None, eps)
                    lp = loss_fn(backing.unflatten(wp), b)
                    lm = loss_fn(backing.unflatten(wm), b)
                    g = (lp - lm) / (2.0 * eps)
                    return zo_fused_update_flat(delta_dense, z_flat, None,
                                                -lr * g), g

                d0 = jnp.zeros((backing.n_pad,), jnp.float32)
                d_T, gs = jax.lax.scan(one, d0, (keys, batches_c))
                return backing.restrict(d_T), gs

            deltas, gs = jax.vmap(client_run)(batches)
        agg = jnp.mean(deltas, axis=0)                    # [n] sparse collective
        return space.add(params, agg), gs

    return round_step
