"""Production federated-ZO train steps (what the dry-run lowers).

On the TPU mesh, FL clients are the (pod, data) shards.  With the shared
per-step seeds of Alg. 2/3, every client perturbs with the *same* z, so the
high-frequency (T=1) MEERKAT step is exactly:

    z  = N(0, I_n)                       (n = sparse coords, same everywhere)
    f+ = per-client loss at w + eps*z    (pure data-parallel forward)
    f- = per-client loss at w - eps*z
    g_k = (f+_k - f-_k) / 2 eps          (K scalars)
    w' = w - lr * mean_k(g_k) * z        (one sparse scatter)

The only cross-client collective is the scalar mean — the paper's 1000x
communication saving, visible structurally in the lowered HLO.

Both step factories dispatch between the fused flat-vector Pallas route and
the pytree reference route (``core/dispatch.py``).  On the flat route the
perturb phase is one ``zo_dual_perturb_flat`` HBM pass producing both
perturbed copies and the weight update one ``zo_fused_update_flat`` pass —
versus three chained full-tree scatter passes on the reference route.

``make_fl_round_step`` is the T>1 variant (clients' deltas diverge within a
round, so clients are vmapped; used by simulations and small-scale runs).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.dispatch import get_backing, resolve_backend
from repro.kernels.ops import zo_dual_perturb_flat, zo_fused_update_flat


def _masked_mean(g_clients, report_mask):
    """Survivor/cohort mean of the per-client scalars: ``None`` (and an
    all-ones mask) is the plain mean; a 0/1 mask excludes clients as a
    *runtime operand* — one compiled program for every fault pattern and
    every sampled cohort."""
    if report_mask is None:
        return jnp.mean(g_clients)
    m = report_mask.astype(g_clients.dtype)
    return jnp.sum(g_clients * m) / jnp.maximum(jnp.sum(m), 1.0)


def make_fl_train_step(per_example_loss: Callable, space, *, eps: float,
                       lr: float, n_clients: int, constrain_params=None,
                       backend: Optional[str] = None, quantize=None):
    """T=1 high-frequency MEERKAT step (Alg. 3). Returns jittable fn
    (params, key, batch) -> (params', g_clients [K], metrics).

    ``quantize`` (:class:`repro.core.quantize.QuantSpec`) rounds each
    client's scalar to the uplink wire grid before the collective — the
    compiled-path form of the fleet uplink codec: the aggregated g is
    the mean of exactly the values the server dequantizes.

    ``constrain_params`` re-applies the parameter sharding after each sparse
    scatter — the flat-index scatter otherwise erases GSPMD's weight
    shardings and replicates all downstream matmuls (see DESIGN.md §perf).
    When it is set, backend="auto" resolves to the pytree route: flattening
    a tensor-parallel weight is not GSPMD-representable, so the fused flat
    kernels are reserved for the unsharded / FSDP-only regimes.

    The optional trailing ``report_mask`` ([K] 0/1) is the compiled-path
    dropout model: clients whose upload was lost are excluded from the
    scalar collective — ``g = sum(mask * g_k) / max(1, sum(mask))`` — so
    the step aggregates over survivors without recompiling per fault
    pattern (the mask is a runtime operand).  ``None`` (and an all-ones
    mask) is exactly the fault-free mean."""
    cp = constrain_params or (lambda p: p)

    def step(params, key, batch, report_mask=None):
        backing = get_backing(space, params)
        be = resolve_backend(backend, backing,
                             sharded=constrain_params is not None)
        z = space.sample_z(key)
        if be == "ref":
            w_plus = cp(space.add(params, eps * z))
            l_plus = per_example_loss(w_plus, batch)          # [B_global]
            w_minus = cp(space.add(w_plus, (-2.0 * eps) * z))  # in-place chain
            l_minus = per_example_loss(w_minus, batch)
        else:
            w_flat = backing.flatten(params)
            z_flat = backing.expand(z)
            wp, wm = zo_dual_perturb_flat(w_flat, z_flat, None, eps)
            l_plus = per_example_loss(cp(backing.unflatten(wp)), batch)
            l_minus = per_example_loss(cp(backing.unflatten(wm)), batch)
        g_clients = (l_plus - l_minus).reshape(n_clients, -1).mean(-1) \
            / (2.0 * eps)
        if quantize is not None:
            g_clients = quantize.apply(g_clients, key)
        g = _masked_mean(g_clients, report_mask)          # scalar collective
        if be == "ref":
            new_params = cp(space.add(w_minus, (eps - lr * g) * z))
        else:
            new_params = cp(backing.unflatten(zo_fused_update_flat(
                w_flat, z_flat, None, -lr * g)))
        metrics = {"loss": jnp.mean(l_plus + l_minus) / 2.0, "g": g}
        return new_params, g_clients, metrics

    return step


# Below this many backed parameters the per-step cost is dominated by op
# dispatch, and stacking (w+, w-) into one vmapped forward halves the
# dispatch count; above it the forwards are compute/memory-bound and the
# 2x-batch stacked matmuls lose to two sequential forwards (measured on
# both bench arches: tiny wants stacked, qwen3-4b-reduced wants
# sequential — BENCH_zo_step.json).
STACK_FORWARDS_MAX_PARAMS = 1 << 20


def make_fl_train_loop(per_example_loss: Callable, space, *, eps: float,
                       lr: float, n_clients: int, n_steps: int,
                       backend: Optional[str] = None,
                       stack_forwards: Optional[bool] = None,
                       constrain_params=None, quantize=None):
    """``n_steps`` T=1 high-frequency MEERKAT steps in one jitted scan —
    the compiled training burst (the serving engine's decode-burst idea
    applied to the train loop: no host round-trip per step).

    Returns jittable (params, key, batches[, report_masks]) -> (params',
    g_clients [n_steps, K], metrics), with batches carrying a leading
    [n_steps, ...] axis.  Semantically identical to folding
    :func:`make_fl_train_step` over the batches.

    The optional trailing ``report_masks`` ([n_steps, K] 0/1) is the
    per-step survivor/cohort mask, a *runtime operand* scanned alongside
    the batches: sampled cohorts and dropout patterns change per step
    without recompiling.  ``quantize`` mirrors
    :func:`make_fl_train_step`: per-client scalars are rounded to the
    uplink wire grid (key folded per step) before the masked mean.

    On the fused route the flat parameter vector is built ONCE before the
    scan and carried dense across it — the per-step
    ``backing.flatten(params)`` / tile re-padding round-trip that repeated
    single-step calls pay (and that inverted the e2e fused-vs-naive
    comparison on qwen3_4b in BENCH_zo_step) is hoisted; each scanned step
    is exactly one fused dual-perturb pass, the two forwards, and one
    fused update pass.

    ``stack_forwards`` picks how the fused route evaluates the (w+, w-)
    pair: True stacks both into one vmapped 2x-batch forward (halves op
    dispatch — wins when the model is small enough that dispatch dominates),
    False runs two sequential forwards (wins once the forwards are
    compute-bound and the 2x-batch matmuls stop fitting cache).  None
    auto-selects by backed-parameter count (STACK_FORWARDS_MAX_PARAMS).

    ``constrain_params`` is the mesh route (mirroring
    :func:`make_fl_train_step`): it re-applies the plan's weight shardings
    after every sparse scatter inside the scanned burst, and forces
    ``backend="auto"`` onto the pytree route — the flat carry is not
    GSPMD-representable for sharded weights (DESIGN.md §perf/§9)."""
    cp = constrain_params or (lambda p: p)

    def loop(params, key, batches, report_masks=None):
        backing = get_backing(space, params)
        keys = jax.random.split(key, n_steps)
        xs = ((keys, batches) if report_masks is None
              else (keys, batches, report_masks))

        def unpack(inp):
            return inp if report_masks is not None else (*inp, None)

        def g_of(l_plus, l_minus, k):
            g_cl = (l_plus - l_minus).reshape(n_clients, -1).mean(-1) \
                / (2.0 * eps)
            if quantize is not None:
                g_cl = quantize.apply(g_cl, k)
            return g_cl

        if resolve_backend(backend, backing,
                           sharded=constrain_params is not None) == "ref":
            def one(p, inp):
                k, b, mask = unpack(inp)
                z = space.sample_z(k)
                w_plus = cp(space.add(p, eps * z))
                l_plus = per_example_loss(w_plus, b)
                w_minus = cp(space.add(w_plus, (-2.0 * eps) * z))
                l_minus = per_example_loss(w_minus, b)
                g_cl = g_of(l_plus, l_minus, k)
                g = _masked_mean(g_cl, mask)
                new_p = cp(space.add(w_minus, (eps - lr * g) * z))
                return new_p, (g_cl, (l_plus + l_minus).mean() / 2.0)

            p_T, (gs, losses) = jax.lax.scan(one, params, xs)
            return p_T, gs, {"loss": losses[-1], "g": gs[-1].mean()}

        w0 = backing.flatten(params)  # hoisted: once per burst, not per step
        # one dense z buffer carried across the burst: the coordinate set
        # is static, so each step overwrites only the sparse values in
        # place instead of re-materializing n_pad zeros (scatter_into)
        z0 = jnp.zeros((backing.n_pad,), jnp.float32)
        stack = (backing.n_flat <= STACK_FORWARDS_MAX_PARAMS
                 if stack_forwards is None else stack_forwards)

        def one(carry, inp):
            w_flat, z_buf = carry
            k, b, mask = unpack(inp)
            z_flat = backing.scatter_into(z_buf, space.sample_z(k))
            wp, wm = zo_dual_perturb_flat(w_flat, z_flat, None, eps)
            if stack:
                # one vectorized forward over the stacked (w+, w-) pair:
                # identical math (vmap), half the per-step op dispatches on
                # the loss side — the small-model bottleneck the flat route
                # pays twice
                both = jax.vmap(per_example_loss, in_axes=(0, None))(
                    jax.vmap(lambda f: cp(backing.unflatten(f)))(
                        jnp.stack([wp, wm])), b)
                l_plus, l_minus = both[0], both[1]
            else:
                l_plus = per_example_loss(cp(backing.unflatten(wp)), b)
                l_minus = per_example_loss(cp(backing.unflatten(wm)), b)
            g_cl = g_of(l_plus, l_minus, k)
            g = _masked_mean(g_cl, mask)
            new_w = zo_fused_update_flat(w_flat, z_flat, None, -lr * g)
            return (new_w, z_flat), (g_cl, (l_plus + l_minus).mean() / 2.0)

        (w_T, _), (gs, losses) = jax.lax.scan(one, (w0, z0), xs)
        return (cp(backing.unflatten(w_T)), gs,
                {"loss": losses[-1], "g": gs[-1].mean()})

    return loop


def make_fl_round_step(loss_fn: Callable, space, *, eps: float, lr: float,
                       T: int, backend: Optional[str] = None):
    """Full MEERKAT round with T>1 local steps and vmapped clients.

    batches: pytree with leading [K, T, b, ...]; keys: [T] (shared across
    clients per Alg. 2).  Returns (params', gs [K, T]).

    Flat route: the parameter vector is flattened once per round; each
    vmapped client carries its dense flat delta through the T-step scan with
    one fused dual-perturb + one fused update pass per step."""

    def client_run_ref(params, keys, batches_c):
        def one(delta, inp):
            key, b = inp
            z = space.sample_z(key)
            lp = loss_fn(space.add(params, delta + eps * z), b)
            lm = loss_fn(space.add(params, delta - eps * z), b)
            g = (lp - lm) / (2.0 * eps)
            return delta - lr * g * z, g

        delta0 = jnp.zeros((space.n,), jnp.float32)
        return jax.lax.scan(one, delta0, (keys, batches_c))

    def round_step(params, keys, batches):
        backing = get_backing(space, params)
        n_cl = jax.tree_util.tree_leaves(batches)[0].shape[0]
        if resolve_backend(backend, backing, dense_carry=n_cl) == "ref":
            deltas, gs = jax.vmap(client_run_ref, in_axes=(None, None, 0))(
                params, keys, batches)
        else:
            w_flat = backing.flatten(params)

            def client_run(batches_c):
                def one(delta_dense, inp):
                    key, b = inp
                    z_flat = backing.expand(space.sample_z(key))
                    wp, wm = zo_dual_perturb_flat(w_flat + delta_dense,
                                                  z_flat, None, eps)
                    lp = loss_fn(backing.unflatten(wp), b)
                    lm = loss_fn(backing.unflatten(wm), b)
                    g = (lp - lm) / (2.0 * eps)
                    return zo_fused_update_flat(delta_dense, z_flat, None,
                                                -lr * g), g

                d0 = jnp.zeros((backing.n_pad,), jnp.float32)
                d_T, gs = jax.lax.scan(one, d0, (keys, batches_c))
                return backing.restrict(d_T), gs

            deltas, gs = jax.vmap(client_run)(batches)
        agg = jnp.mean(deltas, axis=0)                    # [n] sparse collective
        return space.add(params, agg), gs

    return round_step
