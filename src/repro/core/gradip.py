"""GradIP score (paper Definition 2.3) and trajectory computation.

GradIP_t = < grad_f_pretrain , grad_hat_k^t >  where grad_hat_k^t is the
ZO-reconstructed client gradient.  In sparse coordinates this is simply
``g_k^t * dot(gp[mask], z_t)`` — the server never materializes dense
gradients.

The inner reduction dispatches like the other hot paths
(``core/dispatch.py`` pattern):

* ``backend="pallas"`` — the blocked Pallas reduction
  (``kernels/gradip_reduce.py`` via ``kernels/ops.gradip_flat``): ``gp``
  and each ``z_t`` stream once through a (R, 128)-tiled VMEM accumulator.
* ``backend="ref"``    — plain ``jnp.dot``; the only route for traced or
  mesh-sharded ``gp`` vectors (a pallas_call cannot consume a
  GSPMD-sharded operand, so the sharded server keeps GradIP on the
  replicated host copy — DESIGN.md §9).
* ``backend=None``/"auto" picks pallas for concrete single-device
  vectors, ref otherwise.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _resolve_gradip_backend(backend: Optional[str], gp_vec) -> str:
    """'auto'/None -> 'pallas' | 'ref' for a given [n] gp vector.

    Traced values (inside an outer jit) and mesh-committed sharded arrays
    take the jnp route; concrete single-device vectors take the kernel."""
    backend = backend or "auto"
    if backend in ("pallas", "ref"):
        return backend
    if backend != "auto":
        raise ValueError(f"gradip backend must be auto|pallas|ref, "
                         f"got {backend!r}")
    if isinstance(gp_vec, jax.core.Tracer):
        return "ref"
    try:
        sharded = len(gp_vec.sharding.device_set) > 1
    except AttributeError:  # numpy input
        sharded = False
    return "ref" if sharded else "pallas"


def gradip_trajectory(space, keys, gs, gp_vec,
                      backend: Optional[str] = None):
    """Per-step GradIP of one client's virtual path.

    Args:
      space: the sparse coordinate space (``sample_z`` regenerates each
        step's direction from the shared seed ladder).
      keys: [T] PRNG keys (the round's seed list).
      gs: [T] f32 projected-gradient scalars uploaded by the client
        (units: loss per unit step along z).
      gp_vec: [n] f32 pre-training gradient restricted to the space.
      backend: reduction route, see module docstring.

    Returns (gradip [T], grad_norm [T], cosine [T]) — all f32:
    ``gradip_t = g_t * <gp, z_t>``, ``grad_norm_t = |g_t| * ||z_t||``
    (the reconstructed ZO gradient's L2 norm), and the cosine similarity
    between the reconstructed gradient and ``gp``."""
    gp = gp_vec.astype(jnp.float32)
    gp_norm = jnp.linalg.norm(gp) + 1e-12
    be = _resolve_gradip_backend(backend, gp_vec)

    if be == "pallas":
        from repro.kernels.ops import gradip_flat

        def one(_, inp):
            key, g = inp
            z = space.sample_z(key)
            ip = gradip_flat(gp, z, g)
            gnorm = jnp.abs(g) * jnp.linalg.norm(z)
            cos = ip / (gp_norm * gnorm + 1e-12)
            return None, (ip, gnorm, cos)

        _, (ips, norms, coss) = jax.lax.scan(one, None, (keys, gs))
        return ips, norms, coss

    def one(key, g):
        z = space.sample_z(key)
        ip = g * jnp.dot(gp, z)
        gnorm = jnp.abs(g) * jnp.linalg.norm(z)
        cos = ip / (gp_norm * gnorm + 1e-12)
        return ip, gnorm, cos

    ips, norms, coss = jax.vmap(one)(keys, gs)
    return ips, norms, coss


def gradip_matrix(entries, T: Optional[int] = None):
    """Stack one client's per-round GradIP log into a dense matrix with
    explicit gaps.

    ``entries`` is ``FederatedZO.gradip_log[cid]`` — one [T_r] array per
    round the client reported, ``None`` for rounds it was dropped,
    straggling (until arrival), or **unsampled** (fleet-scale client
    sampling logs a gap for every client outside the round's cohort, so
    trajectory analyses see the participation structure instead of a
    silently shortened log).

    Returns ``(mat [R, T] f32, present [R] bool)``: gap rounds are NaN
    rows; shorter entries (e.g. an early-stopped client's T=1 rounds)
    are NaN-padded on the right.  ``T`` defaults to the longest present
    entry and must be given when the log is all gaps."""
    entries = list(entries)
    present = np.array([e is not None for e in entries], bool)
    lens = [int(np.asarray(e).reshape(-1).shape[0])
            for e in entries if e is not None]
    if T is None:
        if not lens:
            raise ValueError("gradip_matrix: all-gap log needs explicit T")
        T = max(lens)
    mat = np.full((len(entries), int(T)), np.nan, np.float32)
    for i, e in enumerate(entries):
        if e is not None:
            row = np.asarray(e, np.float32).reshape(-1)
            mat[i, :row.shape[0]] = row
    return mat, present


def pretrain_gradient_vec(loss_fn, params, space, batches):
    """Server-held pre-training gradient restricted to the space.

    Args:
      loss_fn: scalar LM loss ``(params, batch) -> f32``.
      params: parameter pytree (unsharded — the gradient is a first-order
        calibration pass run once, before any mesh placement).
      space: sparse coordinate space (``slice`` restricts the gradient).
      batches: iterable of C4-proxy batches.

    Returns the mean gradient over the batches at the space's
    coordinates: [n] f32."""
    from repro.models.layers import differentiable_attn
    grad_fn = jax.jit(jax.grad(loss_fn))
    acc = jnp.zeros((space.n,), jnp.float32)
    n = 0
    for b in batches:
        with differentiable_attn():  # grad-appropriate attn route
            acc = acc + space.slice(grad_fn(params, b))
        n += 1
    return acc / max(n, 1)
