"""GradIP score (paper Definition 2.3) and trajectory computation.

GradIP_t = < grad_f_pretrain , grad_hat_k^t >  where grad_hat_k^t is the
ZO-reconstructed client gradient.  In sparse coordinates this is simply
``g_k^t * dot(gp[mask], z_t)`` — the server never materializes dense
gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gradip_trajectory(space, keys, gs, gp_vec):
    """gs: [T] projected gradients; gp_vec: [n] pre-training gradient slice.

    Returns (gradip [T], grad_norm [T], cosine [T])."""
    gp = gp_vec.astype(jnp.float32)
    gp_norm = jnp.linalg.norm(gp) + 1e-12

    def one(key, g):
        z = space.sample_z(key)
        ip = g * jnp.dot(gp, z)
        gnorm = jnp.abs(g) * jnp.linalg.norm(z)
        cos = ip / (gp_norm * gnorm + 1e-12)
        return ip, gnorm, cos

    ips, norms, coss = jax.vmap(one)(keys, gs)
    return ips, norms, coss


def pretrain_gradient_vec(loss_fn, params, space, batches):
    """Server-held pre-training gradient restricted to the space: [n]."""
    from repro.models.layers import differentiable_attn
    grad_fn = jax.jit(jax.grad(loss_fn))
    acc = jnp.zeros((space.n,), jnp.float32)
    n = 0
    for b in batches:
        with differentiable_attn():  # no VJP on the pallas attn route
            acc = acc + space.slice(grad_fn(params, b))
        n += 1
    return acc / max(n, 1)
