"""Transferable sparse-mask selection (paper §2.1).

MEERKAT's mask marks the top-``u`` fraction of parameters by *average squared
gradient on pre-training data* (the C4 proxy corpus here).  Baselines:
weight-magnitude, random.  Masks are static for the whole FL run and
transferable across downstream tasks.
"""
from __future__ import annotations

from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spaces import MaskedSpace


def _n_select(total: int, density: float) -> int:
    return max(1, int(round(total * density)))


def sensitivity_scores(loss_fn: Callable, params, batches: Iterable):
    """Average squared per-parameter gradient over pre-training batches."""
    from repro.models.layers import differentiable_attn
    grad_fn = jax.jit(jax.grad(loss_fn))
    acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    n = 0
    for batch in batches:
        with differentiable_attn():  # grad-appropriate attn route
            g = grad_fn(params, batch)
        acc = jax.tree.map(lambda a, gg: a + jnp.square(gg.astype(jnp.float32)),
                           acc, g)
        n += 1
    return jax.tree.map(lambda a: a / max(n, 1), acc)


def _global_topk_indices(score_tree, density: float):
    """Per-leaf int32 flat-index arrays of the global top-k scores."""
    leaves, treedef = jax.tree_util.tree_flatten(score_tree)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    total = sum(sizes)
    k = _n_select(total, density)
    flat = np.concatenate([np.asarray(l, np.float32).ravel() for l in leaves])
    top = np.argpartition(flat, -k)[-k:]
    top = np.sort(top)
    offsets = np.concatenate([[0], np.cumsum(sizes)])
    idx_leaves = []
    for i in range(len(leaves)):
        sel = top[(top >= offsets[i]) & (top < offsets[i + 1])] - offsets[i]
        idx_leaves.append(jnp.asarray(sel, jnp.int32))
    return jax.tree_util.tree_unflatten(treedef, idx_leaves)


def sensitivity_mask(loss_fn, params, pretrain_batches, density: float
                     ) -> MaskedSpace:
    """MEERKAT's mask: global top-u by avg squared pre-training gradient."""
    scores = sensitivity_scores(loss_fn, params, pretrain_batches)
    return MaskedSpace(_global_topk_indices(scores, density))


def magnitude_mask(params, density: float) -> MaskedSpace:
    """Weight-magnitude baseline: top-u by |w|."""
    scores = jax.tree.map(lambda p: jnp.abs(p.astype(jnp.float32)), params)
    return MaskedSpace(_global_topk_indices(scores, density))


def random_mask(params, density: float, seed: int = 0,
                balanced: bool = True) -> MaskedSpace:
    """Uniform random mask.  ``balanced`` selects round(n_i * u) coords per
    leaf (the shard-friendly layout used for the large-arch dry-runs)."""
    rng = np.random.default_rng(seed)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    sizes = [int(np.prod(np.asarray(l.shape))) for l in leaves]
    if balanced:
        idx_leaves = []
        for s in sizes:
            k = max(1, int(round(s * density)))
            idx_leaves.append(jnp.asarray(
                np.sort(rng.choice(s, size=min(k, s), replace=False)),
                jnp.int32))
    else:
        total = sum(sizes)
        k = _n_select(total, density)
        top = np.sort(rng.choice(total, size=k, replace=False))
        offsets = np.concatenate([[0], np.cumsum(sizes)])
        idx_leaves = [jnp.asarray(
            top[(top >= offsets[i]) & (top < offsets[i + 1])] - offsets[i],
            jnp.int32) for i in range(len(leaves))]
    return MaskedSpace(jax.tree_util.tree_unflatten(treedef, idx_leaves))


def abstract_mask(abstract_params, density: float,
                  max_coords: int = 8_388_608):
    """Index-tree of ShapeDtypeStructs for the dry-run (no allocation).

    Density is clamped so the coordinate count stays <= ``max_coords``
    (the paper validates densities down to 5e-5, Table 7) — for
    trillion-parameter archs we dry-run at the smaller density.
    """
    leaves, treedef = jax.tree_util.tree_flatten(abstract_params)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    total = sum(sizes)
    eff_density = min(density, max_coords / total)
    shapes = [jax.ShapeDtypeStruct((max(1, int(s * eff_density)),), jnp.int32)
              for s in sizes]
    return jax.tree_util.tree_unflatten(treedef, shapes), eff_density


def concrete_balanced_mask_like(abstract_idx_tree, abstract_params, seed=0):
    """Concrete random indices matching an abstract mask (for smoke tests)."""
    rng = np.random.default_rng(seed)
    p_leaves = jax.tree_util.tree_leaves(abstract_params)
    i_leaves, treedef = jax.tree_util.tree_flatten(abstract_idx_tree)
    out = []
    for p, i in zip(p_leaves, i_leaves):
        size = int(np.prod(p.shape))
        k = min(int(i.shape[0]), size)
        out.append(jnp.asarray(
            np.sort(rng.choice(size, size=k, replace=False)), jnp.int32))
    return jax.tree_util.tree_unflatten(treedef, out)
