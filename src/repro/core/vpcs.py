"""Virtual-Path Client Selection (paper Algorithm 1).

From each client's GradIP trajectory over a calibration phase, compute

* rho_later  = mean(GradIP over initial phase) / mean(GradIP over later phase)
* rho_quie   = fraction of later-phase steps with |GradIP| < sigma

Clients whose rho_later or rho_quie exceed the thresholds are flagged as
extremely Non-IID and early-stopped to T=1 local step per round.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.configs.base import FLConfig


@dataclass
class VPCSResult:
    """Per-client VPCS verdict.

    ``rho_later``: mean |GradIP| over the initial phase divided by the
    later-phase mean (dimensionless; > ``fl.vp_rho_later`` flags).
    ``rho_quie``: fraction of later-phase steps with |GradIP| below sigma
    (in [0, 1]; > ``fl.vp_rho_quie`` flags).
    ``flagged``: client is extreme Non-IID — early-stop to T=1."""
    rho_later: float
    rho_quie: float
    flagged: bool


def analyze_trajectory(gradip: np.ndarray, fl: FLConfig) -> VPCSResult:
    """Apply Alg. 1 steps 2-3 to one client's GradIP trajectory.

    ``gradip``: [T_cali] GradIP scalars (units: squared-gradient inner
    product — loss²/param²; only relative magnitudes matter, |.| is taken
    internally).  Phase lengths come from ``fl.vp_init_steps`` /
    ``fl.vp_later_steps``, clamped to the trajectory length.

    With ``fl.vp_sigma_relative`` the quiescence threshold is
    ``vp_sigma * mean(|GradIP|) over the initial phase`` instead of the
    paper's absolute sigma — GradIP magnitudes scale with model size and
    mask density, so an absolute threshold tuned at 1-3B params does not
    transfer; the relative form is scale-free (beyond-paper robustness)."""
    g = np.abs(np.asarray(gradip, np.float64))
    t_init = min(fl.vp_init_steps, len(g))
    t_later = min(fl.vp_later_steps, len(g))
    init_avg = float(g[:t_init].mean())
    later = g[-t_later:]
    later_avg = float(later.mean())
    rho_later = init_avg / (later_avg + 1e-12)
    sigma = (fl.vp_sigma * init_avg if fl.vp_sigma_relative else fl.vp_sigma)
    rho_quie = float((later < sigma).mean())
    flagged = (rho_later > fl.vp_rho_later) or (rho_quie > fl.vp_rho_quie)
    return VPCSResult(rho_later, rho_quie, flagged)


def select_clients(trajectories: Sequence[np.ndarray], fl: FLConfig):
    """Apply :func:`analyze_trajectory` to every client.

    ``trajectories``: one [T_cali] GradIP array per client, indexed by
    client id.  Returns (results: [VPCSResult per client], flagged:
    sorted list of flagged client ids)."""
    results = [analyze_trajectory(t, fl) for t in trajectories]
    flagged = [k for k, r in enumerate(results) if r.flagged]
    return results, flagged
