"""Federated orchestration: MEERKAT (Alg. 2), high-frequency MEERKAT (Alg. 3),
MEERKAT-VP (Alg. 1) and the baselines (Full-FedZO, weight-magnitude mask,
random mask, LoRA-FedZO, random-early-stop).

The server *never* sees client data: it receives only projected-gradient
scalars and replays virtual paths from the shared seed ladder.  For
simulation speed, clients with the same local-step count T are executed as a
single vmapped jit call; the *aggregated update is always computed from the
server-side virtual-path reconstruction* of the uploaded scalars (exactness
vs the client-side trajectory is unit-tested).

**Mesh route** (``plan=``, a :class:`repro.sharding.fl.FLShardPlan`): the
same round executes sharded on a device mesh — parameters per
``sharding/rules.py`` (FSDP by default), the vmapped client axis over the
``('pod','data')`` batch axes.  Everything the virtual-path replay consumes
(seed keys, the [K, T] scalars, GradIP inputs) is gathered to host first,
so reconstruction, aggregation, GradIP trajectories and VPCS decisions are
bit-identical to the single-device path (DESIGN.md §9; parity-tested by
``tools/fl_mesh_parity.py``).

**Fault tolerance** (DESIGN.md §11): ``run_round(faults=)`` tolerates
clients dropping (aggregate over survivors) and straggling (bounded
staleness, seed-replayed exactly at arrival), and
``save_checkpoint``/``load_checkpoint`` snapshot/restore the complete
server state for bit-exact resume after a kill — including across mesh
shapes.  Deterministic fault schedules come from
``repro.fault.FaultPlan``.

**Fleet scale** (DESIGN.md §12): with ``fl.sample_frac < 1`` each round
runs a seeded fixed-size cohort (``core/sampling.ClientSampler``; fault
events restrict to the sampled cohort, unsampled clients get explicit
GradIP gaps), and ``fl.quantize`` routes the scalar uplink through the
``core/quantize`` codec — clients apply the wire-grid values in-loop
(exact replay), so the server reconstructs virtual paths from the
*dequantized* upload bit-exactly.  Server state stays O(seeds + scalars)
in the fleet size K: parameters + per-client scalars only, never
K x model (``checkpoint/state.server_state_sizes`` accounts it).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import seeds as S
from repro.core import virtual_path as VP
from repro.core import vpcs as VPCS
from repro.core import zo as ZO
from repro.core.gradip import gradip_trajectory
from repro.core.quantize import make_codec
from repro.core.sampling import ClientSampler


class Client:
    """Holds a local dataset and a data pointer (paper §2.5: flagged clients
    resume from where they stopped so all data is eventually used).

    ``data``: dict of equally-long numpy arrays (leading dim = examples);
    ``batch_size``: examples per local step."""

    def __init__(self, cid: int, data: Dict[str, np.ndarray], batch_size: int):
        self.cid = cid
        self.data = data
        self.batch_size = batch_size
        self.ptr = 0
        self.n = len(next(iter(data.values())))

    def next_batches(self, T: int):
        """Stack of T batches — each value [T, batch_size, ...] — advancing
        the pointer with wraparound."""
        idx = (self.ptr + np.arange(T * self.batch_size)) % self.n
        self.ptr = int((self.ptr + T * self.batch_size) % self.n)
        sel = {k: v[idx] for k, v in self.data.items()}
        return {k: v.reshape(T, self.batch_size, *v.shape[1:])
                for k, v in sel.items()}


def _per_step(g: np.ndarray) -> np.ndarray:
    """Reduce a client's uploaded scalars to one per local step: [T] stays
    [T]; multi-direction [T, K] averages over K (the K directions estimate
    the same step gradient, so their mean is the step's GradIP scalar)."""
    g = np.asarray(g)
    return g.mean(axis=1) if g.ndim > 1 else g


@dataclass
class CommLog:
    """Cumulative FL protocol traffic in **bytes** (f32 scalars = 4 B each;
    seeds = 8 B).  Counts the paper's client<->server payloads only —
    intra-mesh collective traffic is measured separately from compiled HLO
    (``benchmarks/fl_scale_bench.py``)."""
    up_bytes: int = 0
    down_bytes: int = 0

    def add(self, up: int, down: int):
        self.up_bytes += int(up)
        self.down_bytes += int(down)


class FederatedZO:
    """Generic sparse-ZO FL server; the ``space`` argument selects the method
    (MEERKAT sensitivity mask / magnitude / random / dense / LoRA).

    Args:
      loss_fn: scalar client loss ``(params, batch) -> f32`` (mean over the
        batch).
      params: initial parameter pytree.  With ``plan`` set it is committed
        to the mesh per the plan's rule at construction.
      space: coordinate space (``core/spaces.py``) — defines ``n``, z
        sampling, and the sparse scatter.
      fl: :class:`FLConfig` hyper-parameters.
      clients: the client fleet (``Client`` instances).
      eval_fn: optional jitted ``(params, batch) -> {metric: f32}``.
      high_freq: force Alg. 3 downlink accounting; default T==1.
      plan: optional :class:`repro.sharding.fl.FLShardPlan` — run every
        client group sharded on the plan's mesh (see module docstring).
      sampler: optional :class:`repro.core.sampling.ClientSampler`
        override; by default one is built from ``fl.sample_frac < 1``
        (seeded with ``fl.seed``, weighted by client data size when
        ``fl.sample_weighted``).  ``None`` with ``sample_frac == 1``
        runs the whole fleet every round (today's dense protocol).
      codec: optional uplink codec override (``core/quantize.py``); by
        default built from ``fl.quantize`` (``"none"`` = raw f32).

    The vmapped client loops dispatch through ``fl.zo_backend``
    ("auto" routes the per-step perturb/update through the fused flat
    Pallas kernels when the layout supports it; see core/dispatch.py).
    Under a ``plan`` the auto backend resolves to the pytree route, whose
    N-D scatters keep weight leaves sharded."""

    def __init__(self, loss_fn: Callable, params, space, fl: FLConfig,
                 clients: Sequence[Client], eval_fn: Optional[Callable] = None,
                 high_freq: Optional[bool] = None, plan=None, sampler=None,
                 codec=None):
        self.loss_fn = loss_fn
        self.space = space
        self.fl = fl
        self.plan = plan
        self.params = params if plan is None else plan.place_params(params)
        self.backend = getattr(fl, "zo_backend", "auto")
        self.clients = list(clients)
        self.eval_fn = eval_fn
        self.high_freq = fl.local_steps == 1 if high_freq is None else high_freq
        self.codec = codec if codec is not None else make_codec(
            getattr(fl, "quantize", "none"))
        if sampler is None:
            frac = float(getattr(fl, "sample_frac", 1.0))
            if frac < 1.0:
                weights = ([c.n for c in self.clients]
                           if getattr(fl, "sample_weighted", False) else None)
                sampler = ClientSampler([c.cid for c in self.clients],
                                        frac=frac, weights=weights,
                                        seed=fl.seed)
        self.sampler = sampler
        self.comm = CommLog()
        self.round = 0
        self.history: List[Dict[str, Any]] = []
        self.early_stopped: set = set()
        self.velocity = None  # FedAvgM server momentum state (beyond-paper)
        self.gradip_log: Dict[int, list] = {c.cid: [] for c in self.clients}
        # straggler uploads in flight: dicts of (arrive, cid, src_round,
        # gip_idx, gs) — part of the checkpointed state (DESIGN.md §11)
        self._pending: List[dict] = []
        self.last_round_info: Optional[dict] = None
        self._batch_runs: Dict[int, Callable] = {}
        self._recon = jax.jit(
            lambda keys, gs: jax.vmap(
                lambda g: VP.reconstruct_delta(self.space, keys, g,
                                               self.fl.lr))(gs))

    # -- jitted vmapped T-step client group (one compile per distinct
    # (T, group width); the width feeds the auto backend's dense-carry
    # budget, so a small early-stopped group isn't penalized for the
    # fleet size) ------------------------------------------------------
    def _batch_run_for(self, T: int, n_group: int, template_batches=None):
        """Jitted ``(params, keys [T], batches [K, T, b, ...]) ->
        (deltas [K, n], gs [K, T] or [K, T, n_dirs])`` for a group of
        ``n_group`` same-T clients.

        Clients are processed with ``jax.lax.map`` — each client's T-step
        loop runs as an *unbatched* program, so the per-client bits are
        independent of group width and of how the client axis is sharded
        (the mesh-parity invariant; DESIGN.md §9).  Under a ``plan`` the
        group is wrapped in ``shard_map`` (``FLShardPlan.shard_group``):
        client axis over the mesh batch axes, parameters gathered at round
        entry.  ``rule="tp"`` instead keeps GSPMD tensor-parallel compute
        (``compute_view``) — allclose-level parity only."""
        key = (T, n_group)
        if key not in self._batch_runs:
            run = ZO.make_local_run(self.loss_fn, self.space, self.fl.eps,
                                    self.fl.lr,
                                    n_dirs=getattr(self.fl, "n_dirs", 1),
                                    backend=self.backend,
                                    n_carries=n_group,
                                    sharded=self.plan is not None,
                                    quantize=self.codec.jax_spec())

            def group(params, keys, batches):
                zeros = jnp.zeros((self.space.n,), jnp.float32)
                return jax.lax.map(lambda b: run(params, keys, b, zeros),
                                   batches)

            if self.plan is None:
                self._batch_runs[key] = jax.jit(group)
            elif self.plan.rule == "tp":
                def group_tp(params, keys, batches):
                    return group(self.plan.compute_view(params), keys,
                                 batches)
                self._batch_runs[key] = jax.jit(group_tp)
            else:
                n_dirs = getattr(self.fl, "n_dirs", 1)
                self._batch_runs[key] = jax.jit(self.plan.shard_group(
                    group, template_batches, n_group,
                    out_ndims=(2, 3 if n_dirs > 1 else 2)))
        return self._batch_runs[key]

    def _client_T(self, cid: int) -> int:
        return 1 if cid in self.early_stopped else self.fl.local_steps

    def _cohort(self, r: int) -> tuple:
        """Participating client ids for round ``r``: the whole fleet
        without a sampler, else the sampler's seeded draw — sorted and
        of fixed size, so every round reuses one compiled group program
        (the cohort is data, not shape)."""
        if self.sampler is None:
            return tuple(c.cid for c in self.clients)
        return self.sampler.cohort(r)

    @staticmethod
    def _stack(batch_list):
        return {k: jnp.asarray(np.stack([b[k] for b in batch_list]))
                for k in batch_list[0]}

    def _place_group(self, keys, batches, n_group: int):
        """Mesh route: commit the group's inputs — keys replicated, the
        stacked batches' client axis over ('pod','data')."""
        if self.plan is None:
            return keys, batches
        return (self.plan.place_replicated(keys),
                self.plan.place_client_batches(batches, n_group))

    # -- one federated round (Alg. 2 + the failure model) --------------------
    def run_round(self, gp_vec=None, faults=None):
        """Execute one round: group clients by local-step count T, run each
        group's local ZO loops (vmapped; sharded under a ``plan``), account
        the scalar uploads, reconstruct every client's virtual path from
        (seed list, scalars) on the host, aggregate, and apply the update.

        ``gp_vec`` ([n] pre-training gradient): also log each client's
        GradIP trajectory for this round.  Returns {cid: gs [T] or
        [T, n_dirs]} — the scalars each client uploaded *this round*.

        ``faults`` (a :class:`repro.fault.RoundFaults`) injects the
        failure model:

        * ``drops`` — offline clients: no local steps, no traffic, data
          pointer frozen, an explicit ``None`` gap in ``gradip_log``.
        * ``late`` (cid -> delay) — stragglers: they run this round's
          local steps on its seeds/data, but the scalar upload lands
          ``delay`` rounds later.  Because the seed ladder derives every
          key from ``(fl.seed, round, T)``, the server replays the stale
          virtual path bit-exactly at arrival (``VP.reconstruct_delta``
          with the *source* round's keys).  Uplink bytes are counted at
          arrival — ``CommLog`` records traffic when it happens.
        * ``kill`` — SIGKILL the server mid-round (after client compute,
          before the update applies): the preemption the checkpoint/
          resume path recovers from.

        With a sampler (``fl.sample_frac < 1``) only the round's seeded
        cohort participates: fault events restrict to the cohort
        (``RoundFaults.restrict``), unsampled clients run nothing, move
        no bytes, keep their data pointers, and get an explicit ``None``
        GradIP gap.  Every upload crosses the wire through
        ``self.codec``: the server bills the *encoded* byte count and
        stores/replays the *decoded* scalars — bit-identical to what the
        client applied locally (exact-replay quantization in
        ``core/zo.py``), so the virtual path stays reconstructible from
        the compressed uplink.

        The round aggregates over whoever actually reported — prompt
        survivors plus stragglers landing this round — via the
        survivor-count-aware :func:`VP.aggregate`; a zero-reporter round
        applies a zero update.  Diagnostics land in
        ``self.last_round_info``."""
        from repro.fault.plan import NO_FAULTS
        f = faults if faults is not None else NO_FAULTS
        r = self.round
        cohort = self._cohort(r)
        in_cohort = set(cohort)
        f = f.restrict(in_cohort)
        if gp_vec is not None:
            for c in self.clients:
                if c.cid not in in_cohort:
                    self.gradip_log[c.cid].append(None)  # unsampled gap
        groups: Dict[int, List[Client]] = {}
        for c in self.clients:
            if c.cid in in_cohort:
                groups.setdefault(self._client_T(c.cid), []).append(c)
        # deterministic grouping: sorted-T iteration below, and each cohort
        # client in exactly one group — resume replay and the mesh-parity
        # harness must never depend on dict insertion order or see a
        # client twice
        cids = [c.cid for cs in groups.values() for c in cs]
        assert len(cids) == len(in_cohort) == len(set(cids)), \
            "each cohort client must appear in exactly one T-group"
        deltas, gs_by_cid, arrived = [], {}, []
        for T in sorted(groups):
            if gp_vec is not None:
                for c in groups[T]:
                    if c.cid in f.drops:
                        self.gradip_log[c.cid].append(None)  # explicit gap
            cs = [c for c in groups[T] if c.cid not in f.drops]
            if not cs:
                continue
            keys = S.round_keys(self.fl.seed, r, T)
            batches = self._stack([c.next_batches(T) for c in cs])
            grp = self._batch_run_for(T, len(cs), template_batches=batches)
            keys_d, batches = self._place_group(keys, batches, len(cs))
            # (1) clients run T local ZO steps; upload the scalars g_k^{1..T}
            _, gs = grp(self.params, keys_d, batches)
            # (2) server reconstructs each client's virtual path from
            #     (seed list, scalars) — no data, no dense vectors.  The
            #     scalars are gathered to host first so replay/aggregation
            #     run identically under any mesh shape (DESIGN.md §9).
            # uplink: every scalar block crosses the wire through the
            # codec; the *decoded* values are what the server stores,
            # bills and replays (identical to the client's applied
            # values — exact-replay quantization), and the billed bytes
            # are the encoded wire size
            wires = [self.codec.encode(g) for g in np.asarray(gs)]
            gs = np.stack([self.codec.decode(w) for w in wires])
            prompt = [i for i, c in enumerate(cs) if c.cid not in f.late]
            if prompt:
                deltas.append(np.asarray(self._recon(
                    keys, jnp.asarray(gs[np.asarray(prompt)]))))
            for i, c in enumerate(cs):
                g = gs[i]
                if c.cid in f.late:
                    # straggler: the downlink happened (it participated),
                    # the upload is in flight until its arrival round
                    self.comm.add(up=0, down=self._down_bytes(T))
                    gip_idx = -1
                    if gp_vec is not None:
                        self.gradip_log[c.cid].append(None)
                        gip_idx = len(self.gradip_log[c.cid]) - 1
                    self._pending.append(dict(
                        arrive=r + int(f.late[c.cid]), cid=c.cid,
                        src_round=r, gip_idx=gip_idx, gs=g))
                    continue
                gs_by_cid[c.cid] = g
                # upload = every projected-gradient scalar block (T with
                # n_dirs=1, T*K multi-direction) at the codec's wire size
                self.comm.add(up=wires[i].nbytes, down=self._down_bytes(T))
                if gp_vec is not None:
                    ips, _, _ = gradip_trajectory(self.space, keys,
                                                  jnp.asarray(_per_step(g)),
                                                  gp_vec)
                    self.gradip_log[c.cid].append(np.asarray(ips))
        # (2b) stragglers landing this round: replay their virtual path with
        # the *source* round's seed keys — exact, because the seed ladder is
        # a pure function of (fl.seed, round, T); fill the GradIP gap logged
        # at the source round (deterministic order: by source round then cid)
        due = sorted((p for p in self._pending if p["arrive"] <= r),
                     key=lambda p: (p["src_round"], p["cid"]))
        self._pending = [p for p in self._pending if p["arrive"] > r]
        for p in due:
            gs_l = np.asarray(p["gs"])
            src_keys = S.round_keys(self.fl.seed, p["src_round"],
                                    gs_l.shape[0])
            deltas.append(np.asarray(self._recon(src_keys,
                                                 jnp.asarray(gs_l[None]))))
            self.comm.add(up=self.codec.nbytes(gs_l.size), down=0)
            if gp_vec is not None and p["gip_idx"] >= 0:
                ips, _, _ = gradip_trajectory(self.space, src_keys,
                                              jnp.asarray(_per_step(gs_l)),
                                              gp_vec)
                self.gradip_log[p["cid"]][p["gip_idx"]] = np.asarray(ips)
            arrived.append((p["cid"], p["src_round"], gs_l))
        if f.kill:
            from repro.fault import plan as _fault_plan
            _fault_plan.kill_now()  # mid-round: work done, update not applied
        # (3) aggregate the reconstructed sparse updates of whoever reported
        # (+ optional FedAvgM server momentum — beyond-paper)
        n_report = sum(int(d.shape[0]) for d in deltas)
        if n_report:
            agg = VP.aggregate(
                jnp.concatenate([jnp.asarray(d) for d in deltas], axis=0),
                n_report)
        else:  # zero-survivor round: well-defined no-op update
            agg = jnp.zeros((self.space.n,), jnp.float32)
        if self.fl.server_momentum > 0.0:
            self.velocity = (agg if self.velocity is None
                             else self.fl.server_momentum * self.velocity
                             + agg)
            agg = self.velocity
        if self.plan is not None:
            agg = self.plan.place_replicated(agg)
        self.params = self.space.add(self.params, agg)
        self.round += 1
        self.last_round_info = dict(
            round=r, n_reporting=n_report, drops=sorted(f.drops),
            late=dict(f.late), arrived=arrived,
            pending=len(self._pending), cohort=list(cohort),
            n_unsampled=len(self.clients) - len(cohort))
        return gs_by_cid

    def _down_bytes(self, T: int) -> int:
        """Per-client downlink bytes for a T-step round (Alg. 2/3)."""
        if self.high_freq:
            # aggregated scalars + next seed; with the K-direction
            # estimator clients replay mean_k g_tk * z_tk, so all T*K
            # per-direction scalars must come down (mirrors the uplink)
            return 4 * T * getattr(self.fl, "n_dirs", 1) + 8
        return 4 * self.space.n  # sparse (or dense/LoRA) model refresh

    # -- calibration + VPCS (MEERKAT-VP, Alg. 1) ----------------------------
    def calibrate_vp(self, gp_vec, T_cali: Optional[int] = None):
        """Run the calibration phase (round index -1 in the seed ladder),
        analyze GradIP trajectories, flag extreme Non-IID clients for
        early stopping.

        ``gp_vec``: [n] pre-training gradient at the space coordinates;
        ``T_cali``: calibration steps (default
        ``fl.vp_calibration_steps``).  Returns (results
        [:class:`repro.core.vpcs.VPCSResult` per client], flagged client
        id list, trajectories [list of GradIP [T_cali] arrays])."""
        T = T_cali or self.fl.vp_calibration_steps
        keys = S.round_keys(self.fl.seed, -1, T)
        batches = self._stack([c.next_batches(T) for c in self.clients])
        grp = self._batch_run_for(T, len(self.clients),
                                  template_batches=batches)
        keys_d, batches = self._place_group(keys, batches, len(self.clients))
        _, gs = grp(self.params, keys_d, batches)
        trajs = []
        for c, g in zip(self.clients, np.asarray(gs)):
            ips, _, _ = gradip_trajectory(self.space, keys,
                                          jnp.asarray(_per_step(g)), gp_vec)
            trajs.append(np.asarray(ips))
            c.ptr = 0  # calibration does not consume training order
        results, flagged = VPCS.select_clients(trajs, self.fl)
        self.early_stopped = set(flagged)
        return results, flagged, trajs

    def early_stop_random(self, n: int, seed: int = 0):
        """Random-client-selection baseline: early-stop n random clients."""
        rng = np.random.default_rng(seed)
        ids = rng.choice([c.cid for c in self.clients], size=n, replace=False)
        self.early_stopped = set(int(i) for i in ids)

    # -- fault tolerance: snapshot / restore ---------------------------------
    def save_checkpoint(self, path: str) -> str:
        """Atomically snapshot the full server state (params, velocity,
        round, CommLog, GradIP trajectories + gaps, VPCS flags, client
        data pointers, straggler queue, history) to ``path``
        (``checkpoint/state.py``; bit-exact resume, any mesh plan)."""
        from repro.checkpoint.state import save_server_state
        return save_server_state(path, self)

    def load_checkpoint(self, path: str) -> dict:
        """Restore a :meth:`save_checkpoint` snapshot into this server
        (config-fingerprint checked; params re-placed per this server's
        ``plan``, so the checkpoint may come from a different mesh
        shape).  Returns the checkpoint meta dict."""
        from repro.checkpoint.state import restore_server_state
        return restore_server_state(path, self)

    # -- training loop -------------------------------------------------------
    def run(self, rounds: int, eval_every: int = 0, eval_batch=None,
            gp_vec=None, verbose: bool = False, fault_plan=None,
            checkpoint_dir=None, checkpoint_every: int = 0):
        """Run ``rounds`` federated rounds; evaluate every ``eval_every``
        rounds with ``eval_fn(params, eval_batch)``.  Returns the history
        list of metric dicts (each tagged with its round index).

        ``fault_plan`` (a :class:`repro.fault.FaultPlan`) injects that
        plan's per-round drop/late/kill events.  With ``checkpoint_dir``
        set, the server snapshot is written to
        ``<dir>/ckpt_latest.msgpack`` every ``checkpoint_every`` rounds
        (after eval, so the history is captured); cadence and eval use
        the *global* round index, so a resumed run checkpoints and
        evaluates on the same schedule as an uninterrupted one."""
        import os
        from repro.checkpoint.state import LATEST_NAME
        for _ in range(rounds):
            faults = (fault_plan.round_faults(self.round)
                      if fault_plan is not None else None)
            self.run_round(gp_vec=gp_vec, faults=faults)
            if eval_every and self.round % eval_every == 0 \
                    and self.eval_fn is not None:
                m = self.eval_fn(self.params, eval_batch)
                m = {k: float(v) for k, v in m.items()}
                m["round"] = self.round
                self.history.append(m)
                if verbose:
                    print(f"  round {self.round}: " +
                          " ".join(f"{k}={v:.4f}" for k, v in m.items()
                                   if k != "round"))
            if checkpoint_dir and checkpoint_every \
                    and self.round % checkpoint_every == 0:
                self.save_checkpoint(os.path.join(checkpoint_dir,
                                                  LATEST_NAME))
        return self.history
