"""Federated orchestration: MEERKAT (Alg. 2), high-frequency MEERKAT (Alg. 3),
MEERKAT-VP (Alg. 1) and the baselines (Full-FedZO, weight-magnitude mask,
random mask, LoRA-FedZO, random-early-stop).

The server *never* sees client data: it receives only projected-gradient
scalars and replays virtual paths from the shared seed ladder.  For
simulation speed, clients with the same local-step count T are executed as a
single vmapped jit call; the *aggregated update is always computed from the
server-side virtual-path reconstruction* of the uploaded scalars (exactness
vs the client-side trajectory is unit-tested).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core import seeds as S
from repro.core import virtual_path as VP
from repro.core import vpcs as VPCS
from repro.core import zo as ZO
from repro.core.gradip import gradip_trajectory


class Client:
    """Holds a local dataset and a data pointer (paper §2.5: flagged clients
    resume from where they stopped so all data is eventually used)."""

    def __init__(self, cid: int, data: Dict[str, np.ndarray], batch_size: int):
        self.cid = cid
        self.data = data
        self.batch_size = batch_size
        self.ptr = 0
        self.n = len(next(iter(data.values())))

    def next_batches(self, T: int):
        """Stack of T batches, advancing the pointer with wraparound."""
        idx = (self.ptr + np.arange(T * self.batch_size)) % self.n
        self.ptr = int((self.ptr + T * self.batch_size) % self.n)
        sel = {k: v[idx] for k, v in self.data.items()}
        return {k: v.reshape(T, self.batch_size, *v.shape[1:])
                for k, v in sel.items()}


def _per_step(g: np.ndarray) -> np.ndarray:
    """Reduce a client's uploaded scalars to one per local step: [T] stays
    [T]; multi-direction [T, K] averages over K (the K directions estimate
    the same step gradient, so their mean is the step's GradIP scalar)."""
    g = np.asarray(g)
    return g.mean(axis=1) if g.ndim > 1 else g


@dataclass
class CommLog:
    up_bytes: int = 0
    down_bytes: int = 0

    def add(self, up: int, down: int):
        self.up_bytes += int(up)
        self.down_bytes += int(down)


class FederatedZO:
    """Generic sparse-ZO FL server; the ``space`` argument selects the method
    (MEERKAT sensitivity mask / magnitude / random / dense / LoRA).

    The vmapped client loops dispatch through ``fl.zo_backend``
    ("auto" routes the per-step perturb/update through the fused flat
    Pallas kernels when the layout supports it; see core/dispatch.py)."""

    def __init__(self, loss_fn: Callable, params, space, fl: FLConfig,
                 clients: Sequence[Client], eval_fn: Optional[Callable] = None,
                 high_freq: Optional[bool] = None):
        self.loss_fn = loss_fn
        self.params = params
        self.space = space
        self.fl = fl
        self.backend = getattr(fl, "zo_backend", "auto")
        self.clients = list(clients)
        self.eval_fn = eval_fn
        self.high_freq = fl.local_steps == 1 if high_freq is None else high_freq
        self.comm = CommLog()
        self.round = 0
        self.history: List[Dict[str, Any]] = []
        self.early_stopped: set = set()
        self.velocity = None  # FedAvgM server momentum state (beyond-paper)
        self.gradip_log: Dict[int, list] = {c.cid: [] for c in self.clients}
        self._batch_runs: Dict[int, Callable] = {}
        self._recon = jax.jit(
            lambda keys, gs: jax.vmap(
                lambda g: VP.reconstruct_delta(self.space, keys, g,
                                               self.fl.lr))(gs))

    # -- jitted vmapped T-step client group (one compile per distinct
    # (T, group width); the width feeds the auto backend's dense-carry
    # budget, so a small early-stopped group isn't penalized for the
    # fleet size) ------------------------------------------------------
    def _batch_run_for(self, T: int, n_group: int):
        key = (T, n_group)
        if key not in self._batch_runs:
            run = ZO.make_local_run(self.loss_fn, self.space, self.fl.eps,
                                    self.fl.lr,
                                    n_dirs=getattr(self.fl, "n_dirs", 1),
                                    backend=self.backend,
                                    n_carries=n_group)

            def group(params, keys, batches):
                zeros = jnp.zeros((self.space.n,), jnp.float32)
                return jax.vmap(lambda b: run(params, keys, b, zeros))(batches)

            self._batch_runs[key] = jax.jit(group)
        return self._batch_runs[key]

    def _client_T(self, cid: int) -> int:
        return 1 if cid in self.early_stopped else self.fl.local_steps

    @staticmethod
    def _stack(batch_list):
        return {k: jnp.asarray(np.stack([b[k] for b in batch_list]))
                for k in batch_list[0]}

    # -- one federated round (Alg. 2) ---------------------------------------
    def run_round(self, gp_vec=None):
        r = self.round
        groups: Dict[int, List[Client]] = {}
        for c in self.clients:
            groups.setdefault(self._client_T(c.cid), []).append(c)
        deltas, gs_by_cid = [], {}
        for T, cs in groups.items():
            keys = S.round_keys(self.fl.seed, r, T)
            batches = self._stack([c.next_batches(T) for c in cs])
            # (1) clients run T local ZO steps; upload the scalars g_k^{1..T}
            _, gs = self._batch_run_for(T, len(cs))(self.params, keys,
                                                     batches)
            # (2) server reconstructs each client's virtual path from
            #     (seed list, scalars) — no data, no dense vectors.
            deltas.append(self._recon(keys, gs))
            for c, g in zip(cs, np.asarray(gs)):
                gs_by_cid[c.cid] = g
                # upload = every projected-gradient scalar: T with n_dirs=1,
                # T*K for the multi-direction estimator ([T, K] gs)
                self.comm.add(up=4 * g.size, down=self._down_bytes(T))
                if gp_vec is not None:
                    ips, _, _ = gradip_trajectory(self.space, keys,
                                                  jnp.asarray(_per_step(g)),
                                                  gp_vec)
                    self.gradip_log[c.cid].append(np.asarray(ips))
        # (3) aggregate reconstructed sparse updates (+ optional FedAvgM
        # server momentum on the sparse value vector — beyond-paper)
        agg = VP.aggregate(jnp.concatenate(deltas, axis=0))
        if self.fl.server_momentum > 0.0:
            self.velocity = (agg if self.velocity is None
                             else self.fl.server_momentum * self.velocity
                             + agg)
            agg = self.velocity
        self.params = self.space.add(self.params, agg)
        self.round += 1
        return gs_by_cid

    def _down_bytes(self, T: int) -> int:
        if self.high_freq:
            # aggregated scalars + next seed; with the K-direction
            # estimator clients replay mean_k g_tk * z_tk, so all T*K
            # per-direction scalars must come down (mirrors the uplink)
            return 4 * T * getattr(self.fl, "n_dirs", 1) + 8
        return 4 * self.space.n  # sparse (or dense/LoRA) model refresh

    # -- calibration + VPCS (MEERKAT-VP, Alg. 1) ----------------------------
    def calibrate_vp(self, gp_vec, T_cali: Optional[int] = None):
        """Run the calibration phase, analyze GradIP trajectories, flag
        extreme Non-IID clients for early stopping."""
        T = T_cali or self.fl.vp_calibration_steps
        keys = S.round_keys(self.fl.seed, -1, T)
        batches = self._stack([c.next_batches(T) for c in self.clients])
        _, gs = self._batch_run_for(T, len(self.clients))(self.params,
                                                           keys, batches)
        trajs = []
        for c, g in zip(self.clients, np.asarray(gs)):
            ips, _, _ = gradip_trajectory(self.space, keys,
                                          jnp.asarray(_per_step(g)), gp_vec)
            trajs.append(np.asarray(ips))
            c.ptr = 0  # calibration does not consume training order
        results, flagged = VPCS.select_clients(trajs, self.fl)
        self.early_stopped = set(flagged)
        return results, flagged, trajs

    def early_stop_random(self, n: int, seed: int = 0):
        """Random-client-selection baseline: early-stop n random clients."""
        rng = np.random.default_rng(seed)
        ids = rng.choice([c.cid for c in self.clients], size=n, replace=False)
        self.early_stopped = set(int(i) for i in ids)

    # -- training loop -------------------------------------------------------
    def run(self, rounds: int, eval_every: int = 0, eval_batch=None,
            gp_vec=None, verbose: bool = False):
        for _ in range(rounds):
            self.run_round(gp_vec=gp_vec)
            if eval_every and self.round % eval_every == 0 \
                    and self.eval_fn is not None:
                m = self.eval_fn(self.params, eval_batch)
                m = {k: float(v) for k, v in m.items()}
                m["round"] = self.round
                self.history.append(m)
                if verbose:
                    print(f"  round {self.round}: " +
                          " ".join(f"{k}={v:.4f}" for k, v in m.items()
                                   if k != "round"))
        return self.history
