"""Sparse zeroth-order estimator (paper Eq. 1).

g = (f(w + eps*(z(.)m); B) - f(w - eps*(z(.)m); B)) / (2 eps)
grad_hat = g * (z (.) m)

We sample z only at the masked coordinates (space semantics), which is
mathematically identical to the dense ``z (.) m`` formulation.

Every entry point dispatches between two execution routes (see
``core/dispatch.py``):

* ``backend="pallas"`` — the hot path.  Parameters live as one flat [N]
  vector; each perturb phase is a single fused
  :func:`repro.kernels.ops.zo_dual_perturb_flat` pass (one HBM read of
  (w, z, m) producing both perturbed copies) and each update a single
  :func:`repro.kernels.ops.zo_fused_update_flat` pass, instead of chained
  per-leaf pytree scatters.
* ``backend="ref"``    — the original ``space.add`` pytree route (reference
  semantics; required for sharded weights and odd layouts).
* ``backend=None``/"auto" picks pallas whenever the flat layout supports it.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.dispatch import get_backing, resolve_backend
from repro.kernels.ops import zo_dual_perturb_flat, zo_fused_update_flat


def _maybe_quantize(g, key, quantize):
    """Exact-replay quantization hook (core/quantize.py): the client
    rounds each projected-gradient scalar to the wire grid *before*
    applying it, so the value it uploads (and the server dequantizes) is
    bit-identical to the value its local trajectory used.  The rounding
    noise key is the step/direction key folded with QUANT_FOLD — a
    stream disjoint from z sampling, derivable from the seed ladder.
    Identical in the ref and flat-kernel routes (backend bit-parity)."""
    if quantize is None:
        return g
    return quantize.apply(g, key)


def _dual_losses(loss_fn, backing, base_flat, z_flat, eps, batch):
    """Fused perturb + the two loss evaluations; returns (l+, l-).

    z_flat comes pre-masked from ``backing.expand`` (zero off the space
    coordinates), so the kernels run without the mask operand stream."""
    w_plus, w_minus = zo_dual_perturb_flat(base_flat, z_flat, None, eps)
    return (loss_fn(backing.unflatten(w_plus), batch),
            loss_fn(backing.unflatten(w_minus), batch))


def _multi_dir_update(loss_fn, backing, space, base_flat, key, eps: float,
                      n_dirs: int, batch, quantize=None):
    """K-direction fused estimator at ``base_flat``: splits the step key
    into K direction keys (matching ``reconstruct_delta``'s [T, K] replay)
    and returns (mean_k g_k * z_k as a dense flat vector, gs [K]).

    Scanned with a running sum so peak dense memory stays one [n_pad]
    accumulator (not [K, n_pad]) and the loss graph compiles once."""

    def one(acc, k):
        z_flat = backing.expand(space.sample_z(k))
        lp, lm = _dual_losses(loss_fn, backing, base_flat, z_flat, eps,
                              batch)
        g = _maybe_quantize((lp - lm) / (2.0 * eps), k, quantize)
        return acc + g * z_flat, g

    upd_sum, gs = jax.lax.scan(one, jnp.zeros((backing.n_pad,), jnp.float32),
                               jax.random.split(key, n_dirs))
    return upd_sum / n_dirs, gs


def projected_gradient(loss_fn: Callable, params, space, delta, z, eps: float,
                       batch, backend: Optional[str] = None,
                       sharded: bool = False):
    """Scalar projected gradient g at (params + delta) along z.

    ``sharded=True`` declares that ``params`` live sharded on a mesh, so
    ``backend="auto"`` resolves to the pytree route (the flat reshape is
    not GSPMD-representable; see core/dispatch.py)."""
    backing = get_backing(space, params)
    if resolve_backend(backend, backing, sharded=sharded) == "ref":
        lp = loss_fn(space.add(params, delta + eps * z), batch)
        lm = loss_fn(space.add(params, delta - eps * z), batch)
        return (lp - lm) / (2.0 * eps)
    base = backing.flatten(params) + backing.expand(delta)
    lp, lm = _dual_losses(loss_fn, backing, base, backing.expand(z), eps,
                          batch)
    return (lp - lm) / (2.0 * eps)


def local_step(loss_fn: Callable, params, space, delta, key, eps: float,
               lr: float, batch, n_dirs: int = 1,
               backend: Optional[str] = None, sharded: bool = False,
               quantize=None):
    """One client-side ZO step on the sparse delta. Returns (delta', g).

    ``n_dirs > 1`` (beyond-paper) averages the estimator over K independent
    directions per step — K x the forwards for ~1/K x the estimator
    variance (Lemma B.7) while the upload grows only to K scalars per
    step; the virtual path stays reconstructible because the K direction
    keys derive from the shared step key (``reconstruct_delta`` accepts
    gs of shape [T, K]).  n_dirs=1 is exactly the paper's Eq. 1 step.

    ``quantize`` (a :class:`repro.core.quantize.QuantSpec`) rounds each
    g to the uplink wire grid before the update — exact-replay mode: the
    applied scalar equals the dequantized upload bit-for-bit."""
    backing = get_backing(space, params)
    if resolve_backend(backend, backing, sharded=sharded) == "ref":
        return _local_step_ref(loss_fn, params, space, delta, key, eps, lr,
                               batch, n_dirs, quantize)

    base = backing.flatten(params) + backing.expand(delta)
    if n_dirs == 1:
        z = space.sample_z(key)
        lp, lm = _dual_losses(loss_fn, backing, base, backing.expand(z), eps,
                              batch)
        g = _maybe_quantize((lp - lm) / (2.0 * eps), key, quantize)
        return delta - lr * g * z, g

    upd, gs = _multi_dir_update(loss_fn, backing, space, base, key, eps,
                                n_dirs, batch, quantize)
    return delta - lr * backing.restrict(upd), gs


def _local_step_ref(loss_fn, params, space, delta, key, eps, lr, batch,
                    n_dirs, quantize=None):
    if n_dirs == 1:
        z = space.sample_z(key)
        g = projected_gradient(loss_fn, params, space, delta, z, eps, batch,
                               backend="ref")
        g = _maybe_quantize(g, key, quantize)
        return delta - lr * g * z, g

    def one(k):
        z = space.sample_z(k)
        g = projected_gradient(loss_fn, params, space, delta, z, eps, batch,
                               backend="ref")
        g = _maybe_quantize(g, k, quantize)
        return g * z, g

    keys = jax.random.split(key, n_dirs)
    gz, gs = jax.vmap(one)(keys)
    return delta - lr * gz.mean(0), gs


def make_local_run(loss_fn: Callable, space, eps: float, lr: float,
                   n_dirs: int = 1, backend: Optional[str] = None,
                   n_carries: int = 1, sharded: bool = False,
                   quantize=None):
    """Jittable T-step client loop.

    batches: pytree with leading [T, ...]; keys: [T] PRNG keys.
    Returns (delta_T [n], gs [T]) (gs: [T, K] when n_dirs > 1).
    ``n_carries``: how many copies of this run will be vmapped at once
    (clients) — the auto backend budgets its dense flat carries by it.
    ``sharded=True`` (the mesh route of ``FederatedZO``) forces
    ``backend="auto"`` onto the pytree route, whose N-D scatters keep the
    weight leaves sharded (DESIGN.md §9).
    ``quantize`` (:class:`repro.core.quantize.QuantSpec`) turns on
    exact-replay uplink quantization: each step's g is rounded to the
    wire grid before it is applied *and* before it is returned, so the
    trajectory is bit-reconstructible from the quantized upload.

    On the pallas backend the flat parameter vector is built ONCE outside
    the scan and the scan carries the *dense* flat delta, so every local
    step is exactly one fused dual-perturb pass plus one fused update pass
    over HBM — no per-step pytree scatter chain."""

    def run(params, keys, batches, delta0):
        backing = get_backing(space, params)
        if resolve_backend(backend, backing, sharded=sharded,
                           dense_carry=max(1, n_carries)) == "ref":
            def step(delta, inp):
                key, batch = inp
                delta, g = _local_step_ref(loss_fn, params, space, delta,
                                           key, eps, lr, batch, n_dirs,
                                           quantize)
                return delta, g

            return jax.lax.scan(step, delta0, (keys, batches))

        w_flat = backing.flatten(params)
        # dense z buffer carried across the scan: the coordinate set is
        # static, so each step refreshes the sparse values in place
        # (scatter_into) instead of re-materializing n_pad zeros
        z0 = jnp.zeros((backing.n_pad,), jnp.float32)

        def step(carry, inp):
            delta_dense, z_buf = carry
            key, batch = inp
            base = w_flat + delta_dense
            if n_dirs == 1:
                z_flat = backing.scatter_into(z_buf, space.sample_z(key))
                lp, lm = _dual_losses(loss_fn, backing, base, z_flat, eps,
                                      batch)
                g = _maybe_quantize((lp - lm) / (2.0 * eps), key, quantize)
                return (zo_fused_update_flat(delta_dense, z_flat, None,
                                             -lr * g), z_flat), g
            upd, gs = _multi_dir_update(loss_fn, backing, space, base, key,
                                        eps, n_dirs, batch, quantize)
            return (zo_fused_update_flat(delta_dense, upd, None, -lr),
                    z_buf), gs

        (delta_T, _), gs = jax.lax.scan(step, (backing.expand(delta0), z0),
                                        (keys, batches))
        return backing.restrict(delta_T), gs

    return run
