"""Sparse zeroth-order estimator (paper Eq. 1).

g = (f(w + eps*(z(.)m); B) - f(w - eps*(z(.)m); B)) / (2 eps)
grad_hat = g * (z (.) m)

We sample z only at the masked coordinates (space semantics), which is
mathematically identical to the dense ``z (.) m`` formulation.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def projected_gradient(loss_fn: Callable, params, space, delta, z, eps: float,
                       batch):
    """Scalar projected gradient g at (params + delta) along z."""
    lp = loss_fn(space.add(params, delta + eps * z), batch)
    lm = loss_fn(space.add(params, delta - eps * z), batch)
    return (lp - lm) / (2.0 * eps)


def local_step(loss_fn: Callable, params, space, delta, key, eps: float,
               lr: float, batch, n_dirs: int = 1):
    """One client-side ZO step on the sparse delta. Returns (delta', g).

    ``n_dirs > 1`` (beyond-paper) averages the estimator over K independent
    directions per step — K x the forwards for ~1/K x the estimator
    variance (Lemma B.7) while the upload grows only to K scalars per
    step; the virtual path stays reconstructible because the K direction
    keys derive from the shared step key (``reconstruct_delta`` accepts
    gs of shape [T, K]).  n_dirs=1 is exactly the paper's Eq. 1 step."""
    if n_dirs == 1:
        z = space.sample_z(key)
        g = projected_gradient(loss_fn, params, space, delta, z, eps, batch)
        return delta - lr * g * z, g

    def one(k):
        z = space.sample_z(k)
        g = projected_gradient(loss_fn, params, space, delta, z, eps, batch)
        return g * z, g

    keys = jax.random.split(key, n_dirs)
    gz, gs = jax.vmap(one)(keys)
    return delta - lr * gz.mean(0), gs


def make_local_run(loss_fn: Callable, space, eps: float, lr: float,
                   n_dirs: int = 1):
    """Jittable T-step client loop.

    batches: pytree with leading [T, ...]; keys: [T] PRNG keys.
    Returns (delta_T [n], gs [T]).
    """

    def run(params, keys, batches, delta0):
        def step(delta, inp):
            key, batch = inp
            delta, g = local_step(loss_fn, params, space, delta, key, eps, lr,
                                  batch, n_dirs=n_dirs)
            return delta, g

        delta_T, gs = jax.lax.scan(step, delta0, (keys, batches))
        return delta_T, gs

    return run
