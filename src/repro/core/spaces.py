"""Trainable-parameter spaces for sparse zeroth-order optimization.

A *space* is the subset of coordinates that ZO perturbs and updates.  It maps
a flat value vector ``v in R^n`` into the parameter pytree:

* :class:`MaskedSpace` — MEERKAT: ``n = u * d`` sparse coordinates given by
  per-leaf flat indices (paper Eq. 1: ``z (.) m`` — we sample z only at the
  masked coordinates, mathematically identical, O(n) memory).
* :class:`DenseSpace`  — Full-FedZO: all parameters.
* :class:`LoRASpace`   — LoRA-FedZO: all ``lora_*`` adapter leaves.

All operations are jittable; index trees can be abstract for the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _leaves_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), l) for p, l in flat]


class _FlatSpace:
    """Flat-vector backing shared by every space (kernel dispatch).

    Subclasses provide ``leaf_index_arrays(template)`` — per-leaf int32 flat
    indices of the selected coordinates, in the same leaf order as
    ``tree_leaves(template)``.  The derived :class:`repro.core.dispatch.
    FlatBacking` (cached per layout) maps the space into the single flat
    [N] vector the fused Pallas ZO kernels consume.
    """

    def leaf_index_arrays(self, template):
        raise NotImplementedError

    def identity_layout(self) -> bool:
        """True if this space structurally covers every coordinate in
        storage order — lets the backing skip index materialization
        entirely (no O(N) arange build/compare for e.g. Full-FedZO)."""
        return False

    def flat_backing(self, template):
        from repro.core.dispatch import get_backing
        return get_backing(self, template)

    def flatten(self, params):
        """Pytree -> flat [n_pad] vector (leaf-concatenation order, zero
        tail up to the kernels' (8, 128) tile quantum)."""
        return self.flat_backing(params).flatten(params)

    def unflatten(self, flat, template):
        """Flat [n_pad] (or [N]) vector -> pytree with the template's
        shapes/dtypes; the padded tail is ignored."""
        return self.flat_backing(template).unflatten(flat)


class MaskedSpace(_FlatSpace):
    """Sparse coordinate space from per-leaf flat index arrays.

    ``idx_tree`` has the same treedef as ``params``; each leaf is an int32
    array of flat indices into the (raveled) parameter leaf.  Leaves with no
    selected coordinates hold an empty array.
    """

    def __init__(self, idx_tree):
        self.idx_tree = idx_tree
        leaves = jax.tree_util.tree_leaves(idx_tree)
        self.sizes = [int(l.shape[0]) for l in leaves]
        self.offsets = np.concatenate([[0], np.cumsum(self.sizes)]).astype(int)
        self.n = int(self.offsets[-1])

    def sample_z(self, key):
        return jax.random.normal(key, (self.n,), jnp.float32)

    def _segments(self, vec):
        return [vec[self.offsets[i]:self.offsets[i + 1]]
                for i in range(len(self.sizes))]

    def add(self, params, vec):
        """params + scatter(vec) at the masked coordinates.

        Uses N-D scatter indices (``unravel_index`` of the stored flat
        indices) rather than reshaping the leaf to 1-D: a flat reshape is not
        representable for tensor-parallel shardings, so GSPMD would
        all-gather the weight; the N-D scatter keeps the operand sharded and
        only replicates the (tiny) index/update vectors."""
        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        i_leaves = jax.tree_util.tree_leaves(self.idx_tree)
        segs = self._segments(vec)
        out = []
        for p, idx, s in zip(p_leaves, i_leaves, segs):
            if idx.shape[0] == 0:
                out.append(p)
                continue
            nd = jnp.unravel_index(idx, p.shape)
            out.append(p.at[nd].add(s.astype(p.dtype), mode="drop"))
        return jax.tree_util.tree_unflatten(treedef, out)

    def slice(self, tree):
        """Restrict a pytree (e.g. a gradient) to the masked coords -> [n]."""
        t_leaves = jax.tree_util.tree_leaves(tree)
        i_leaves = jax.tree_util.tree_leaves(self.idx_tree)
        segs = [l[jnp.unravel_index(idx, l.shape)].astype(jnp.float32)
                for l, idx in zip(t_leaves, i_leaves)]
        return jnp.concatenate(segs) if segs else jnp.zeros((0,), jnp.float32)

    def leaf_index_arrays(self, template):
        return jax.tree_util.tree_leaves(self.idx_tree)


class DenseSpace(_FlatSpace):
    """All parameters, flattened (Full-FedZO)."""

    def __init__(self, template):
        leaves = jax.tree_util.tree_leaves(template)
        self.template = template
        self.sizes = [int(np.prod(l.shape)) for l in leaves]
        self.offsets = np.concatenate([[0], np.cumsum(self.sizes)]).astype(int)
        self.n = int(self.offsets[-1])

    def sample_z(self, key):
        return jax.random.normal(key, (self.n,), jnp.float32)

    def add(self, params, vec):
        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        out = []
        for i, p in enumerate(p_leaves):
            s = vec[self.offsets[i]:self.offsets[i + 1]]
            out.append(p + s.reshape(p.shape).astype(p.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    def slice(self, tree):
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                                for l in leaves])

    def leaf_index_arrays(self, template):
        return [jnp.arange(int(np.prod(l.shape)), dtype=jnp.int32)
                for l in jax.tree_util.tree_leaves(template)]

    def identity_layout(self) -> bool:
        return True


class LoRASpace(_FlatSpace):
    """Only ``lora_*`` adapter leaves (dense within the adapters)."""

    def __init__(self, template):
        self._is_lora = [("lora_" in path)
                         for path, _ in _leaves_with_paths(template)]
        leaves = jax.tree_util.tree_leaves(template)
        self.sizes = [int(np.prod(l.shape)) if m else 0
                      for l, m in zip(leaves, self._is_lora)]
        self.offsets = np.concatenate([[0], np.cumsum(self.sizes)]).astype(int)
        self.n = int(self.offsets[-1])
        if self.n == 0:
            raise ValueError("no lora_* leaves found; set cfg.lora_rank > 0")

    def sample_z(self, key):
        return jax.random.normal(key, (self.n,), jnp.float32)

    def add(self, params, vec):
        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        out = []
        for i, (p, m) in enumerate(zip(p_leaves, self._is_lora)):
            if not m:
                out.append(p)
                continue
            s = vec[self.offsets[i]:self.offsets[i + 1]]
            out.append(p + s.reshape(p.shape).astype(p.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    def slice(self, tree):
        leaves = jax.tree_util.tree_leaves(tree)
        segs = [l.reshape(-1).astype(jnp.float32)
                for l, m in zip(leaves, self._is_lora) if m]
        return jnp.concatenate(segs)

    def leaf_index_arrays(self, template):
        leaves = jax.tree_util.tree_leaves(template)
        return [jnp.arange(int(np.prod(l.shape)), dtype=jnp.int32) if m
                else jnp.zeros((0,), jnp.int32)
                for l, m in zip(leaves, self._is_lora)]

    def identity_layout(self) -> bool:
        return all(self._is_lora)
