from repro.checkpoint.io import (FORMAT_VERSION, CheckpointError,
                                 load_manifest, load_pytree, save_pytree)
from repro.checkpoint.state import (STATE_VERSION, restore_server_state,
                                    save_server_state)

__all__ = ["CheckpointError", "FORMAT_VERSION", "STATE_VERSION",
           "load_manifest", "load_pytree", "save_pytree",
           "restore_server_state", "save_server_state"]
