"""Msgpack pytree checkpointing with a versioned, checksummed manifest.

One file per checkpoint: ``{version, meta, leaves: {keystr(path):
{dtype, shape, crc32, data}}}``, written atomically (``.tmp`` + fsync +
rename) so a crash mid-write never leaves a half-checkpoint under the
final name.  Every leaf carries a CRC32 of its raw bytes; loading
verifies the format version and every checksum and raises
:class:`CheckpointError` — never a raw msgpack/numpy error — on
truncated, corrupt, or version-mismatched files.

Arrays are gathered to host (fine for the simulation scale; a sharded
implementation would write per-shard files keyed by device index —
layout documented in DESIGN.md §5).  Restoring a checkpoint saved under
one mesh shape onto another therefore needs no resharding pass: the
manifest holds global host arrays and the caller re-places them
(``FLShardPlan.place_params``; see ``checkpoint/state.py``).
"""
from __future__ import annotations

import os
import zlib
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

FORMAT_VERSION = 2


class CheckpointError(RuntimeError):
    """A checkpoint file is unreadable, truncated, corrupt, from a
    different format version, or inconsistent with the restore target."""


def _resolve_dtype(name: str) -> np.dtype:
    """Name -> dtype, covering the ml_dtypes extended types (bfloat16,
    float8_*) whose names plain numpy does not recognize."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _pack_leaf(x):
    a = np.asarray(jax.device_get(x))
    data = a.tobytes()
    # dtype by *name* ('float32', 'bfloat16'): the .str code of an
    # ml_dtypes extended type is an unportable void descriptor
    return {"dtype": a.dtype.name, "shape": list(a.shape),
            "crc32": zlib.crc32(data), "data": data}


def _unpack_leaf(name: str, d):
    try:
        dtype, shape = d["dtype"], d["shape"]
        crc, data = d["crc32"], d["data"]
    except (KeyError, TypeError) as e:
        raise CheckpointError(
            f"leaf {name!r}: malformed manifest entry ({e})") from e
    if zlib.crc32(data) != crc:
        raise CheckpointError(
            f"leaf {name!r}: CRC32 mismatch (corrupt leaf bytes)")
    try:
        # copy out of the read-only frombuffer view: the returned array
        # owns writable memory and outlives the msgpack payload
        a = np.frombuffer(data, dtype=_resolve_dtype(dtype)) \
            .reshape(shape).copy()
    except (ValueError, TypeError, AttributeError) as e:
        raise CheckpointError(f"leaf {name!r}: {e}") from e
    return a


def save_pytree(path: str, tree: Any, metadata: dict | None = None):
    """Atomically write ``tree`` (+ msgpack-able ``metadata``) to ``path``."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {
        "version": FORMAT_VERSION,
        "meta": metadata or {},
        "leaves": {jax.tree_util.keystr(p): _pack_leaf(l) for p, l in flat},
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_manifest(path: str) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Read + verify a checkpoint: returns ``(meta, {keystr: np.ndarray})``.

    Checks the format version and every leaf's CRC32; any failure raises
    :class:`CheckpointError` with the offending leaf/file named."""
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError as e:
        raise CheckpointError(f"cannot read checkpoint {path!r}: {e}") from e
    try:
        payload = msgpack.unpackb(raw, raw=False, strict_map_key=False)
    except Exception as e:  # truncated file, stray bytes, wrong framing
        raise CheckpointError(
            f"{path!r}: truncated or corrupt msgpack payload ({e})") from e
    if not isinstance(payload, dict):
        raise CheckpointError(f"{path!r}: not a checkpoint manifest")
    version = payload.get("version")
    if version != FORMAT_VERSION:
        raise CheckpointError(
            f"{path!r}: checkpoint format version {version!r} != "
            f"supported {FORMAT_VERSION}")
    leaves = payload.get("leaves")
    if not isinstance(leaves, dict):
        raise CheckpointError(f"{path!r}: manifest has no leaves table")
    return payload.get("meta", {}), \
        {name: _unpack_leaf(name, d) for name, d in leaves.items()}


def load_pytree(path: str, template: Any):
    """Load into the structure of ``template`` (shape/dtype-checked)."""
    _, leaves = load_manifest(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, tleaf in flat:
        key = jax.tree_util.keystr(p)
        if key not in leaves:
            raise CheckpointError(f"checkpoint missing leaf {key!r}")
        arr = leaves[key]
        if tuple(arr.shape) != tuple(tleaf.shape):
            raise CheckpointError(f"shape mismatch at {key!r}: "
                                  f"{arr.shape} vs {tleaf.shape}")
        out.append(jnp.asarray(arr.astype(np.dtype(tleaf.dtype))))
    return jax.tree_util.tree_unflatten(treedef, out)
