"""Msgpack pytree checkpointing with a shape/dtype manifest.

Arrays are gathered to host (fine for the simulation scale; a sharded
implementation would write per-shard files keyed by device index — layout
documented in DESIGN.md)."""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x):
    a = np.asarray(x)
    return {b"dtype": a.dtype.str, b"shape": list(a.shape),
            b"data": a.tobytes()}


def _unpack_leaf(d):
    a = np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"]))
    return jnp.asarray(a.reshape(d[b"shape"]))


def save_pytree(path: str, tree: Any, metadata: dict | None = None):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {
        b"meta": metadata or {},
        b"leaves": {jax.tree_util.keystr(p): _pack_leaf(l) for p, l in flat},
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_pytree(path: str, template: Any):
    """Load into the structure of ``template`` (shape/dtype-checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=True)
    leaves = payload[b"leaves"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for p, tleaf in flat:
        key = jax.tree_util.keystr(p).encode()
        if key not in leaves:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = _unpack_leaf(leaves[key])
        if tuple(arr.shape) != tuple(tleaf.shape):
            raise ValueError(f"shape mismatch at {key!r}: "
                             f"{arr.shape} vs {tleaf.shape}")
        out.append(arr.astype(tleaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
