"""Versioned snapshot/restore of the full ``FederatedZO`` server state.

The state inventory (everything a bit-exact resume needs — DESIGN.md
§11): parameters, FedAvgM velocity, the round counter, ``CommLog``
byte counters, per-client GradIP trajectories *including explicit
gaps*, VPCS early-stop flags, per-client data pointers, the straggler
pending-upload queue, the eval history, and a config fingerprint
``(fl.seed, T, n_dirs, K, space.n, lr, eps, sample_frac, quantize,
...)``.  All round randomness is derivable from ``(fl.seed, round, T)``
via the seed ladder (``core/seeds.round_keys``) — including the
exact-replay quantizer's rounding noise — so the only RNG state stored
is the **client sampler's** (state_version 2): its stateful generator
advances one draw per round, and restoring its serialized bit-generator
state makes a resumed server re-draw the killed round's cohort
identically.  Everything else replays the exact uninterrupted
trajectory from the ladder.

:func:`server_state_sizes` accounts the snapshot's bytes, split into
the model-sized part (params, velocity — independent of the fleet size
K) and the per-client scalar part (pointers, GradIP scalars, pending
uploads, sampler state) — the fleet-scale O(seeds + scalars) invariant:
server state never grows as K x model (DESIGN.md §12).

Mesh portability: arrays are gathered to host at save
(``io._pack_leaf`` goes through ``jax.device_get``), and restore
re-places them through the *target* server's plan
(``FLShardPlan.place_params`` / plain ``jnp.asarray``) — so a
checkpoint written under a 2x2 ``FLShardPlan`` restores onto an
unsharded server and vice versa, bit-exactly (FSDP placement never
changes values; DESIGN.md §9).
"""
from __future__ import annotations

import json

import numpy as np

from repro.checkpoint.io import (CheckpointError, load_manifest,
                                 save_pytree)

STATE_VERSION = 2  # v2: + sampler state & fleet config fields

# conventional file names inside a --checkpoint-dir
LATEST_NAME = "ckpt_latest.msgpack"
FINAL_NAME = "ckpt_final.msgpack"

# config fields that must match between checkpoint and restore target:
# they determine the seed ladder, the group programs and the protocol
# accounting, so a mismatch silently breaks bit-exact replay.
_CONFIG_FIELDS = ("seed", "local_steps", "n_dirs", "lr", "eps",
                  "server_momentum", "sample_frac", "sample_weighted",
                  "quantize")


def _keystr(*parts) -> str:
    return "".join(f"['{p}']" for p in parts)


def _config_fingerprint(server) -> dict:
    fl = server.fl
    cfg = {f: getattr(fl, f, None) for f in _CONFIG_FIELDS}
    cfg["n_clients"] = len(server.clients)
    cfg["space_n"] = int(server.space.n)
    cfg["high_freq"] = bool(server.high_freq)
    # effective codec/sampler (catches constructor overrides that the
    # FLConfig fields above would miss)
    cfg["codec"] = getattr(server.codec, "spec", "none")
    cfg["sampler_m"] = (None if server.sampler is None
                        else int(server.sampler.m))
    return cfg


def save_server_state(path: str, server, extra_meta: dict | None = None
                      ) -> str:
    """Write a full server snapshot to ``path`` (atomic; io.py format)."""
    import jax
    tree = {"params": jax.device_get(server.params)}
    if server.velocity is not None:
        tree["velocity"] = np.asarray(jax.device_get(server.velocity))
    gradip, gradip_len = {}, {}
    for cid, entries in server.gradip_log.items():
        gradip_len[str(cid)] = len(entries)
        present = {str(i): np.asarray(e) for i, e in enumerate(entries)
                   if e is not None}
        if present:
            gradip[str(cid)] = present
    if gradip:
        tree["gradip"] = gradip
    pending_meta, pending_gs = [], {}
    for j, ent in enumerate(server._pending):
        pending_meta.append({k: int(ent[k]) for k in
                             ("arrive", "cid", "src_round", "gip_idx")})
        pending_gs[str(j)] = np.asarray(ent["gs"])
    if pending_gs:
        tree["pending"] = pending_gs
    meta = {
        "state_version": STATE_VERSION,
        "round": int(server.round),
        "up_bytes": int(server.comm.up_bytes),
        "down_bytes": int(server.comm.down_bytes),
        "ptrs": {str(c.cid): int(c.ptr) for c in server.clients},
        "early_stopped": sorted(int(c) for c in server.early_stopped),
        "has_velocity": server.velocity is not None,
        "gradip_len": gradip_len,
        "pending": pending_meta,
        "history": server.history,
        "config": _config_fingerprint(server),
        # fleet-scale sampler: full bit-generator state, so a resumed
        # server re-draws the killed round's cohort identically
        "sampler": (None if server.sampler is None
                    else server.sampler.state_dict()),
    }
    if extra_meta:
        meta["extra"] = extra_meta
    save_pytree(path, tree, metadata=meta)
    return path


def _check_config(meta: dict, server, path: str):
    saved = meta.get("config", {})
    here = _config_fingerprint(server)
    diffs = {k: (saved.get(k), here[k]) for k in here
             if saved.get(k) != here[k]}
    if diffs:
        raise CheckpointError(
            f"{path!r}: checkpoint/server config mismatch "
            f"(field: saved vs here): {diffs}")


def restore_server_state(path: str, server) -> dict:
    """Restore a snapshot written by :func:`save_server_state` into
    ``server`` (any mesh plan).  Returns the checkpoint meta dict."""
    import jax
    import jax.numpy as jnp
    meta, leaves = load_manifest(path)
    if meta.get("state_version") != STATE_VERSION:
        raise CheckpointError(
            f"{path!r}: server-state version "
            f"{meta.get('state_version')!r} != supported {STATE_VERSION}")
    _check_config(meta, server, path)

    # -- params: template-checked against the live tree, re-placed per
    # the *target* plan (mesh reshape happens here) ---------------------
    flat, treedef = jax.tree_util.tree_flatten_with_path(server.params)
    out = []
    for p, tleaf in flat:
        key = "['params']" + jax.tree_util.keystr(p)
        if key not in leaves:
            raise CheckpointError(f"{path!r}: missing param leaf {key!r}")
        arr = leaves[key]
        if tuple(arr.shape) != tuple(tleaf.shape):
            raise CheckpointError(
                f"{path!r}: shape mismatch at {key!r}: "
                f"{arr.shape} vs {tleaf.shape}")
        out.append(arr.astype(np.dtype(tleaf.dtype)))
    host_params = jax.tree_util.tree_unflatten(treedef, out)
    if server.plan is not None:
        server.params = server.plan.place_params(host_params)
    else:
        server.params = jax.tree.map(jnp.asarray, host_params)

    server.velocity = (jnp.asarray(leaves[_keystr("velocity")])
                       if meta.get("has_velocity") else None)

    # -- scalar state ----------------------------------------------------
    server.round = int(meta["round"])
    server.comm.up_bytes = int(meta["up_bytes"])
    server.comm.down_bytes = int(meta["down_bytes"])
    server.early_stopped = set(int(c) for c in meta["early_stopped"])
    server.history = list(meta.get("history", []))

    samp = meta.get("sampler")
    if (samp is None) != (server.sampler is None):
        raise CheckpointError(
            f"{path!r}: sampler mismatch: checkpoint "
            f"{'has' if samp is not None else 'lacks'} sampler state but "
            f"the target server "
            f"{'lacks' if server.sampler is None else 'has'} a sampler")
    if samp is not None:
        server.sampler.load_state(samp)

    ptrs = meta["ptrs"]
    have = {str(c.cid) for c in server.clients}
    if set(ptrs) != have:
        raise CheckpointError(
            f"{path!r}: client id mismatch: checkpoint {sorted(ptrs)} "
            f"vs server {sorted(have)}")
    for c in server.clients:
        c.ptr = int(ptrs[str(c.cid)])

    # -- GradIP trajectories with explicit gaps --------------------------
    gradip_len = meta.get("gradip_len", {})
    log = {}
    for c in server.clients:
        n = int(gradip_len.get(str(c.cid), 0))
        log[c.cid] = [leaves.get(_keystr("gradip", str(c.cid), str(i)))
                      for i in range(n)]
    server.gradip_log = log

    # -- straggler pending-upload queue -----------------------------------
    pending = []
    for j, ent in enumerate(meta.get("pending", [])):
        key = _keystr("pending", str(j))
        if key not in leaves:
            raise CheckpointError(f"{path!r}: missing pending leaf {key!r}")
        pending.append(dict(arrive=int(ent["arrive"]), cid=int(ent["cid"]),
                            src_round=int(ent["src_round"]),
                            gip_idx=int(ent["gip_idx"]), gs=leaves[key]))
    server._pending = pending
    return meta


def server_state_sizes(server) -> dict:
    """Byte accounting of the checkpointed server state, split into the
    **model-sized** part (params + optional velocity — independent of
    the fleet size K) and the **per-client scalar** part (data pointers,
    GradIP scalars, pending uploads, sampler state).  The fleet-scale
    invariant (DESIGN.md §12): the per-client part holds a few scalars
    per client — O(seeds + scalars) in K, never K x model — so serving a
    4096-client fleet costs the server the same model footprint as an
    8-client one."""
    import jax
    params = jax.device_get(server.params)
    params_b = sum(np.asarray(x).nbytes
                   for x in jax.tree_util.tree_leaves(params))
    vel_b = (0 if server.velocity is None
             else np.asarray(jax.device_get(server.velocity)).nbytes)
    gradip_b = sum(np.asarray(e).nbytes
                   for entries in server.gradip_log.values()
                   for e in entries if e is not None)
    pending_b = sum(np.asarray(p["gs"]).nbytes for p in server._pending)
    ptr_b = 8 * len(server.clients)
    sampler_b = (0 if server.sampler is None
                 else len(json.dumps(server.sampler.state_dict())))
    return dict(
        n_clients=len(server.clients),
        params_bytes=int(params_b),
        velocity_bytes=int(vel_b),
        model_state_bytes=int(params_b + vel_b),
        gradip_bytes=int(gradip_b),
        pending_bytes=int(pending_b),
        ptr_bytes=int(ptr_b),
        sampler_bytes=int(sampler_b),
        per_client_state_bytes=int(gradip_b + pending_b + ptr_b
                                   + sampler_b),
    )
