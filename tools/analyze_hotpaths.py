"""Repo-root wrapper for the hot-path static analyzer — identical to

    PYTHONPATH=src python -m repro.analysis [args]

(see that CLI's --help; ``repro/analysis/__main__.py`` forces the host
device count before jax loads, which is why this wrapper defers to it
instead of importing the analysis package directly).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
