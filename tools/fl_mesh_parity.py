"""Sharded-vs-single-device parity check for the federated ZO round.

Runs the same MEERKAT problem (tiny model, Non-IID clients, MEERKAT-VP
calibration, T>1 and high-frequency rounds) once unsharded and once per
requested mesh spec (``sharding/fl.FLShardPlan``), then asserts:

* round-aggregated parameters **bit-match** (``rule="fsdp"``/"replicate"),
* per-client GradIP trajectories bit-match,
* VPCS flag sets are identical,
* CommLog byte accounting is identical (the FL protocol traffic must not
  depend on how the round is sharded),
* the ``make_fl_train_loop`` mesh route (global batch over the mesh batch
  axes, ``constrain_params``, mesh ``ShardCtx`` so ``resolve_attn_backend``
  sees the sharded mesh) matches the unsharded loop to float tolerance
  (its in-graph scalar aggregation is a psum whose ordering is
  mesh-dependent — DESIGN.md §9).

The process must be started with enough host devices for the largest
mesh, e.g.::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python tools/fl_mesh_parity.py --meshes 1x1,2x2

``tests/test_fl_mesh_parity.py`` runs exactly that as a subprocess;
CI runs it directly.  Exit code 0 iff every check passes; ``--json PATH``
writes the detailed per-mesh report.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.tiny import TINY
from repro.core import (Client, FederatedZO, pretrain_gradient_vec,
                        random_mask)
from repro.core.fl_step import make_fl_train_loop
from repro.data.corpus import pretrain_batches
from repro.data.partition import dirichlet_partition, subset
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.models import Model
from repro.sharding.fl import make_fl_plan

SPEC = TaskSpec()


def flat_params(tree):
    return np.concatenate([np.asarray(l, np.float32).ravel()
                           for l in jax.tree.leaves(tree)])


def build_problem(seed: int = 0, n_clients: int = 4, density: float = 1e-2):
    model = Model(TINY)
    params = model.init(jax.random.key(seed))
    loss, per_example, evaluate = make_task_fns(model, SPEC)
    space = random_mask(params, density=density, seed=seed + 3,
                        balanced=False)
    pre = pretrain_batches(SPEC, n_batches=2, batch_size=16, seed=seed + 4)
    gp = pretrain_gradient_vec(lambda p, b: model.loss(p, b), params, space,
                               pre)
    train = sample_dataset(SPEC, 512, seed=seed + 1)
    return dict(model=model, params=params, loss=loss,
                per_example=per_example, space=space, gp=gp, train=train)


def make_clients(prob, n_clients: int, batch: int = 16):
    parts = dirichlet_partition(prob["train"]["label"], n_clients, 0.5,
                                seed=0)
    return [Client(k, subset(prob["train"], p), batch)
            for k, p in enumerate(parts)]


def run_server(prob, plan, *, T: int, rounds: int, n_clients: int):
    """One full MEERKAT-VP run; returns everything parity compares.

    ``zo_backend="ref"`` on both sides: the mesh route resolves to the
    pytree backend, so the single-device reference must run the same
    route for a bit-level comparison (pallas-vs-ref parity is covered
    separately in tests/test_dispatch.py)."""
    fl = FLConfig(n_clients=n_clients, local_steps=T, lr=5e-2, eps=1e-3,
                  seed=0, zo_backend="ref", vp_calibration_steps=8,
                  vp_init_steps=4, vp_later_steps=4, vp_rho_later=2.0,
                  vp_sigma=0.25, vp_sigma_relative=True)
    srv = FederatedZO(prob["loss"], prob["params"], prob["space"], fl,
                      make_clients(prob, n_clients), plan=plan)
    _, flagged, _ = srv.calibrate_vp(prob["gp"])
    for _ in range(rounds):
        srv.run_round(gp_vec=prob["gp"])
    return dict(
        params=flat_params(srv.params),
        gradip={cid: np.stack(v) for cid, v in srv.gradip_log.items()},
        flags=sorted(srv.early_stopped),
        comm=(srv.comm.up_bytes, srv.comm.down_bytes))


def run_hf_loop(prob, plan, *, n_steps: int, n_clients: int, batch: int = 8):
    """The ``make_fl_train_loop`` mesh route: global client batch sharded
    over the plan's batch axes, weights constrained per the plan's rule,
    model forwards under the plan's mesh ``ShardCtx`` (this is where
    ``resolve_attn_backend`` sees ``ctx.mesh`` in a real jitted step)."""
    base_model = prob["model"]
    ctx = base_model.ctx if plan is None else plan.shard_ctx(base_model.ctx)
    model = Model(TINY, ctx=ctx)
    _, per_example, _ = make_task_fns(model, SPEC)
    loop = make_fl_train_loop(
        lambda p, b: per_example(p, b), prob["space"], eps=1e-3, lr=5e-2,
        n_clients=n_clients, n_steps=n_steps, backend="ref",
        constrain_params=None if plan is None else plan.constrain_params_fn())
    B = n_clients * batch
    data = sample_dataset(SPEC, n_steps * B, seed=7)
    batches = {k: jnp.asarray(v).reshape(n_steps, B, *v.shape[1:])
               for k, v in data.items()}
    params, key = prob["params"], jax.random.key(11)
    if plan is not None:
        P = jax.sharding.PartitionSpec
        params = plan.place_params(params)
        key = plan.place_replicated(key)
        ba = plan.batch_axes if B % plan.dp == 0 else None
        batches = {k: jax.device_put(v, plan.named(
            P(None, ba, *([None] * (v.ndim - 2)))))
            for k, v in batches.items()}
    p_T, gs, metrics = jax.jit(loop)(params, key, batches)
    return dict(params=flat_params(p_T), gs=np.asarray(gs),
                loss=float(metrics["loss"]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--meshes", default="1x1,2x2",
                    help="comma-separated mesh specs to check against the "
                         "unsharded reference")
    ap.add_argument("--rule", default="fsdp",
                    choices=["fsdp", "tp", "replicate"])
    ap.add_argument("--T", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--hf-steps", type=int, default=4,
                    help="steps for the make_fl_train_loop route check")
    ap.add_argument("--json", default=None, help="write report here")
    a = ap.parse_args()

    bit_exact_rule = a.rule in ("fsdp", "replicate")
    prob = build_problem(n_clients=a.clients)
    ref = run_server(prob, None, T=a.T, rounds=a.rounds,
                     n_clients=a.clients)
    ref_hf = run_hf_loop(prob, None, n_steps=a.hf_steps,
                         n_clients=a.clients)
    report = {"rule": a.rule, "meshes": {}, "ok": True}
    for spec in a.meshes.split(","):
        plan = make_fl_plan(spec=spec, rule=a.rule)
        got = run_server(prob, plan, T=a.T, rounds=a.rounds,
                         n_clients=a.clients)
        got_hf = run_hf_loop(prob, plan, n_steps=a.hf_steps,
                             n_clients=a.clients)
        checks = {
            "params_bitmatch": bool(np.array_equal(ref["params"],
                                                   got["params"])),
            "params_allclose": bool(np.allclose(ref["params"],
                                                got["params"], atol=2e-5)),
            "gradip_bitmatch": all(
                np.array_equal(ref["gradip"][c], got["gradip"][c])
                for c in ref["gradip"]),
            "vpcs_flags_equal": ref["flags"] == got["flags"],
            "comm_bytes_equal": ref["comm"] == got["comm"],
            "hf_loop_allclose": bool(
                np.allclose(ref_hf["params"], got_hf["params"], atol=2e-5)
                and np.allclose(ref_hf["gs"], got_hf["gs"], atol=2e-4)),
        }
        required = ["params_allclose", "vpcs_flags_equal",
                    "comm_bytes_equal", "hf_loop_allclose"]
        if bit_exact_rule:
            required += ["params_bitmatch", "gradip_bitmatch"]
        ok = all(checks[k] for k in required)
        report["meshes"][spec] = {**checks, "ok": ok,
                                  "n_devices": plan.mesh_cfg.n_devices}
        report["ok"] = report["ok"] and ok
        print(f"[{'ok' if ok else 'FAIL'}] mesh {spec} rule={a.rule}: " +
              " ".join(f"{k}={v}" for k, v in checks.items()))
    if a.json:
        os.makedirs(os.path.dirname(a.json) or ".", exist_ok=True)
        with open(a.json, "w") as f:
            json.dump(report, f, indent=1)
        print("wrote", a.json)
    sys.exit(0 if report["ok"] else 1)


if __name__ == "__main__":
    main()
