"""Docs-consistency check: every CLI flag documented in README.md exists
in the corresponding argparse, and every argparse flag is documented.

Pure text processing (no jax import).  Conventions checked:

* README has one flag table per CLI, introduced by a heading containing
  the module path, e.g. ``### \`repro.launch.train\` flags``; table rows
  start with ``| \`--flag\` ...``.
* The source defines flags via ``ap.add_argument("--flag", ...)``.

Also verifies every file referenced in the README "Examples" table
exists.  Exit code 0 iff consistent.

    python tools/check_docs.py            # check
    python tools/check_docs.py --list     # dump both sides per CLI
"""
from __future__ import annotations

import argparse
import os
import re
import sys

REPO = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
CLIS = {
    "repro.launch.train": "src/repro/launch/train.py",
    "repro.launch.serve": "src/repro/launch/serve.py",
    "repro.analysis": "src/repro/analysis/cli.py",
    "repro.kernels.autotune": "src/repro/kernels/autotune.py",
    "benchmarks.fault_bench": "benchmarks/fault_bench.py",
    "benchmarks.fl_scale_bench": "benchmarks/fl_scale_bench.py",
}


def argparse_flags(path: str) -> set:
    src = open(os.path.join(REPO, path)).read()
    return set(re.findall(r'add_argument\(\s*"(--[A-Za-z0-9-]+)"', src))


def readme_sections(readme: str):
    """Split README into (heading, body) chunks at any heading level."""
    parts = re.split(r"^(#{1,6} .*)$", readme, flags=re.M)
    for i in range(1, len(parts) - 1, 2):
        yield parts[i], parts[i + 1]


def readme_flags(readme: str, module: str) -> set:
    for heading, body in readme_sections(readme):
        if module in heading and "flag" in heading.lower():
            return set(re.findall(r"^\|\s*`(--[A-Za-z0-9-]+)", body, re.M))
    return set()


def readme_example_paths(readme: str) -> list:
    return re.findall(r"`(examples/[a-z_0-9]+\.py)`", readme)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true")
    a = ap.parse_args()
    readme = open(os.path.join(REPO, "README.md")).read()
    ok = True
    for module, path in CLIS.items():
        doc = readme_flags(readme, module)
        src = argparse_flags(path)
        if a.list:
            print(f"{module}: documented={sorted(doc)} defined={sorted(src)}")
        if not doc:
            print(f"FAIL {module}: no flag table found in README "
                  f"(want a heading like '### `{module}` flags')")
            ok = False
            continue
        for missing in sorted(doc - src):
            print(f"FAIL {module}: README documents {missing} but "
                  f"{path} does not define it")
            ok = False
        for undoc in sorted(src - doc):
            print(f"FAIL {module}: {path} defines {undoc} but the README "
                  f"flag table omits it")
            ok = False
        if doc == src:
            print(f"ok   {module}: {len(src)} flags consistent")
    paths = readme_example_paths(readme)
    for p in sorted(set(paths)):
        if not os.path.exists(os.path.join(REPO, p)):
            print(f"FAIL README references missing file {p}")
            ok = False
    missing_refs = [f for f in sorted(os.listdir(os.path.join(REPO,
                                                              "examples")))
                    if f.endswith(".py") and f"examples/{f}" not in paths]
    for f in missing_refs:
        print(f"FAIL examples/{f} is not referenced from README")
        ok = False
    if ok:
        print(f"ok   README references all {len(set(paths))} examples")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
