"""Kill-and-recover drill for the federated training driver.

Three subprocess runs of ``repro.launch.train`` on the same problem:

* **A** (reference): uninterrupted, checkpointing every round.
* **B** (victim): identical flags plus ``--kill-at-round k`` — the
  server SIGKILLs itself *mid-round k* (client compute done, update not
  applied), exactly the preemption window the checkpoint protocol must
  survive.  The run must die with ``-SIGKILL`` and leave
  ``ckpt_latest.msgpack`` at round ``k``.
* **C** (recovery): ``--resume`` from B's checkpoint dir, running to the
  same ``--rounds``.

Then the drill asserts B's latest checkpoint is at round ``k`` and that
C's final checkpoint is **bit-identical** to A's: every array leaf, the
round counter, the CommLog byte totals, the per-client data pointers,
the VPCS flags and the eval history.  A SIGKILL costs zero information.
``--sample-frac``/``--quantize`` run the same drill under fleet-scale
client sampling and a quantized uplink: the survivor must restore the
sampler's RNG state (checkpoint meta ``sampler``, compared bit-for-bit
below) so it re-draws the killed round's cohort identically.

Mesh-reshape recovery: ``--mesh-b 2x2`` runs the victim sharded on a
2x2 FLShardPlan while A and C stay unsharded (or pick any combination
with ``--mesh-a/--mesh-c``) — checkpoints are mesh-portable, so the
survivor may restore onto a different topology than the one that died.
Each subprocess forces its own host device count from its ``--mesh``
flag, so the drill itself needs no XLA_FLAGS.  ``--zo-backend ref`` is
pinned on every run: mesh routes resolve to the pytree backend, and
bit-comparison across topologies needs both sides on the same route
(DESIGN.md §9).

CI runs::

    PYTHONPATH=src python tools/kill_recover.py --rounds 4 --kill-at 2

Exit code 0 iff every check passes; ``--json PATH`` writes the report.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.checkpoint.io import load_manifest
from repro.checkpoint.state import FINAL_NAME, LATEST_NAME

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def train_cmd(a, ckpt_dir: str, *, mesh=None, kill_at=None, resume=False):
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", a.arch, "--method", a.method,
           "--rounds", str(a.rounds), "--T", str(a.T),
           "--clients", str(a.clients), "--batch", str(a.batch),
           "--seed", str(a.seed), "--eval-every", str(a.eval_every),
           "--zo-backend", "ref",
           "--checkpoint-dir", ckpt_dir, "--checkpoint-every", "1"]
    if a.sample_frac < 1.0:
        cmd += ["--sample-frac", str(a.sample_frac)]
    if a.quantize != "none":
        cmd += ["--quantize", a.quantize]
    if mesh:
        cmd += ["--mesh", mesh]
    if kill_at is not None:
        cmd += ["--kill-at-round", str(kill_at)]
    if resume:
        cmd += ["--resume"]
    return cmd


def run(cmd, label: str) -> subprocess.CompletedProcess:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.pop("XLA_FLAGS", None)  # each child forces its own device count
    print(f"[{label}] {' '.join(cmd)}")
    p = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                       text=True, timeout=1800)
    tail = "\n".join(p.stdout.strip().splitlines()[-3:])
    print(f"[{label}] rc={p.returncode}\n{tail}")
    if p.returncode not in (0, -signal.SIGKILL):
        print(p.stderr[-2000:], file=sys.stderr)
    return p


def compare_finals(path_a: str, path_c: str) -> dict:
    """Bit-compare two server checkpoints: every leaf + the replay-
    relevant meta."""
    meta_a, leaves_a = load_manifest(path_a)
    meta_c, leaves_c = load_manifest(path_c)
    checks = {"leaf_sets_equal": set(leaves_a) == set(leaves_c)}
    diff = [k for k in leaves_a
            if k in leaves_c and not np.array_equal(leaves_a[k],
                                                    leaves_c[k])]
    checks["leaves_bitmatch"] = checks["leaf_sets_equal"] and not diff
    for field in ("round", "up_bytes", "down_bytes", "ptrs",
                  "early_stopped", "history", "pending", "sampler"):
        checks[f"meta_{field}_equal"] = meta_a.get(field) == meta_c.get(field)
    if diff:
        checks["first_diff_leaf"] = diff[0]
    return checks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--method", default="random",
                    help="space method (random is fast; see launch/train.py)")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--kill-at", type=int, default=2,
                    help="round the victim run SIGKILLs itself in")
    ap.add_argument("--T", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--sample-frac", type=float, default=1.0,
                    help="run the drill under fleet-scale client sampling "
                         "(the survivor must restore the sampler RNG state "
                         "to re-draw the killed round's cohort)")
    ap.add_argument("--quantize", default="none",
                    help="run the drill under a quantized uplink codec "
                         "(none|int8|int4[-nearest])")
    ap.add_argument("--mesh-a", default=None, help="mesh for the reference")
    ap.add_argument("--mesh-b", default=None,
                    help="mesh for the killed run (e.g. 2x2: die sharded, "
                         "recover unsharded)")
    ap.add_argument("--mesh-c", default=None, help="mesh for the recovery")
    ap.add_argument("--workdir", default=None,
                    help="keep checkpoints here (default: tempdir)")
    ap.add_argument("--json", default=None, help="write report here")
    a = ap.parse_args()
    if not 0 < a.kill_at < a.rounds:
        ap.error("--kill-at must be inside (0, --rounds)")

    work = a.workdir or tempfile.mkdtemp(prefix="kill_recover_")
    dir_a, dir_b = os.path.join(work, "ref"), os.path.join(work, "victim")
    os.makedirs(dir_a, exist_ok=True)
    os.makedirs(dir_b, exist_ok=True)
    report = {"args": vars(a), "checks": {}, "ok": False}
    try:
        pa = run(train_cmd(a, dir_a, mesh=a.mesh_a), "A:ref")
        pb = run(train_cmd(a, dir_b, mesh=a.mesh_b, kill_at=a.kill_at),
                 "B:victim")
        checks = report["checks"]
        checks["ref_completed"] = pa.returncode == 0
        checks["victim_sigkilled"] = pb.returncode == -signal.SIGKILL
        latest = os.path.join(dir_b, LATEST_NAME)
        checks["victim_left_latest"] = os.path.exists(latest)
        if checks["victim_left_latest"]:
            meta_b, _ = load_manifest(latest)
            # checkpoint cadence is 1, so the last completed round is k:
            # the kill fires mid-round k, after round k-1's snapshot
            checks["latest_at_kill_round"] = meta_b["round"] == a.kill_at
        pc = run(train_cmd(a, dir_b, mesh=a.mesh_c, resume=True), "C:recover")
        checks["recovery_completed"] = pc.returncode == 0
        checks["resumed_from_kill_round"] = \
            f"resumed from {latest} at round {a.kill_at}" in pc.stdout
        if checks["ref_completed"] and checks["recovery_completed"]:
            checks.update(compare_finals(os.path.join(dir_a, FINAL_NAME),
                                         os.path.join(dir_b, FINAL_NAME)))
        report["ok"] = all(v for k, v in checks.items()
                           if k != "first_diff_leaf")
        for k, v in checks.items():
            print(f"  {k}: {v}")
        print("kill_recover:", "ok" if report["ok"] else "FAIL")
    finally:
        if a.workdir is None:
            shutil.rmtree(work, ignore_errors=True)
    if a.json:
        os.makedirs(os.path.dirname(a.json) or ".", exist_ok=True)
        with open(a.json, "w") as f:
            json.dump(report, f, indent=1)
        print("wrote", a.json)
    sys.exit(0 if report["ok"] else 1)


if __name__ == "__main__":
    main()
