"""End-to-end federated fine-tuning driver on a multi-million-parameter
llama-style model for a few hundred steps (the paper's kind of workload,
CPU-scaled).

    PYTHONPATH=src python examples/train_e2e.py            # ~8M params
    PYTHONPATH=src python examples/train_e2e.py --large    # ~110M params

Covers the full production path: model init, sensitivity-mask calibration
on the C4-proxy corpus, Dirichlet Non-IID partition, MEERKAT-VP GradIP
calibration + early stopping, T>1 rounds with virtual-path aggregation,
checkpointing, and final evaluation.
"""
import argparse
import os
import time

import jax
import numpy as np

from repro.checkpoint.io import load_pytree, save_pytree
from repro.configs.base import FLConfig, ModelConfig
from repro.core import (Client, FederatedZO, pretrain_gradient_vec,
                        sensitivity_mask)
from repro.data.corpus import pretrain_batches
from repro.data.partition import (dirichlet_partition, single_label_partition,
                                  subset)
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.models import Model

SMALL = ModelConfig(name="llama-8m", family="dense", n_layers=4, d_model=256,
                    n_heads=4, n_kv_heads=2, d_ff=704, vocab=2048,
                    tie_embeddings=True, source="llama-3.2 family, CPU-scaled")
LARGE = ModelConfig(name="llama-110m", family="dense", n_layers=12,
                    d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                    vocab=32_000, tie_embeddings=True,
                    source="llama-3.2 family, 100M-class")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--T", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--density", type=float, default=5e-3)
    ap.add_argument("--lr", type=float, default=5e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default="runs/e2e_ckpt.msgpack")
    a = ap.parse_args()

    cfg = LARGE if a.large else SMALL
    spec = TaskSpec(vocab=cfg.vocab, seq_len=32, topic_tokens=64)
    model = Model(cfg)
    params = model.init(jax.random.key(a.seed))
    print(f"{cfg.name}: {model.n_params:,} params")
    loss, per_example, evaluate = make_task_fns(model, spec)
    lm = lambda p, b: model.loss(p, b)

    t0 = time.time()
    pre = pretrain_batches(spec, n_batches=4, batch_size=8, seed=a.seed + 3)
    space = sensitivity_mask(lm, params, pre, density=a.density)
    print(f"sensitivity mask: {space.n:,} coords ({time.time() - t0:.0f}s)")

    train = sample_dataset(spec, 4096, seed=a.seed + 1)
    nb = a.clients * 3 // 4
    parts = (dirichlet_partition(train["label"], nb, alpha=0.5, seed=a.seed)
             + single_label_partition(train["label"], a.clients - nb,
                                      seed=a.seed + 1))
    clients = [Client(k, subset(train, p), a.batch)
               for k, p in enumerate(parts)]
    ev = sample_dataset(spec, 512, seed=a.seed + 2)
    eval_batch = {k: np.asarray(v) for k, v in ev.items()}

    fl = FLConfig(n_clients=a.clients, local_steps=a.T, lr=a.lr, eps=1e-3,
                  density=a.density, seed=a.seed, batch_size=a.batch,
                  vp_calibration_steps=100, vp_init_steps=20,
                  vp_later_steps=20, vp_rho_later=2.0,
                  vp_sigma=0.25, vp_sigma_relative=True)
    server = FederatedZO(loss, params, space, fl, clients, eval_fn=evaluate)

    # MEERKAT-VP: GradIP calibration -> flag extreme Non-IID clients
    gp = pretrain_gradient_vec(lm, params, space, pre)
    _, flagged, _ = server.calibrate_vp(gp)
    print(f"VPCS early-stopped clients: {flagged} "
          f"(true extremes: {list(range(nb, a.clients))})")

    m0 = evaluate(params, eval_batch)
    print(f"round 0: acc={float(m0['acc']):.3f}")
    server.run(a.rounds, eval_every=max(1, a.rounds // 6),
               eval_batch=eval_batch, verbose=True)

    os.makedirs(os.path.dirname(a.ckpt) or ".", exist_ok=True)
    save_pytree(a.ckpt, server.params)
    restored = load_pytree(a.ckpt, server.params)
    m = evaluate(restored, eval_batch)
    total_steps = a.rounds * a.T
    print(f"final (from checkpoint): acc={float(m['acc']):.3f} after "
          f"{total_steps} local steps x {a.clients} clients "
          f"({time.time() - t0:.0f}s)")
    print(f"comm: up={server.comm.up_bytes}B down={server.comm.down_bytes}B "
          f"(dense refresh would be {4 * model.n_params * a.rounds * a.clients:,}B down)")


if __name__ == "__main__":
    main()
