"""Quickstart: MEERKAT sparse-ZO federated fine-tuning in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py               # full demo
    PYTHONPATH=src python examples/quickstart.py --rounds 10   # CI smoke

Builds a tiny decoder LM, selects the transferable sensitivity mask from a
C4-proxy corpus (0.1%-style extreme sparsity, scaled for the tiny model),
partitions a synthetic classification task across 8 Non-IID clients
(Dirichlet alpha=0.5), and runs high-frequency (T=1) MEERKAT rounds —
clients upload one scalar per step, the server reconstructs their virtual
paths and aggregates.  Runs on CPU; the ZO perturb/update dispatches
through the fused Pallas kernels in interpret mode (``--zo-backend``).
"""
import argparse

import jax
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.tiny import TINY
from repro.core import Client, FederatedZO, sensitivity_mask
from repro.data.corpus import pretrain_batches
from repro.data.partition import dirichlet_partition, subset
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.models import Model

ap = argparse.ArgumentParser()
ap.add_argument("--rounds", type=int, default=150)
ap.add_argument("--clients", type=int, default=8)
ap.add_argument("--zo-backend", default="auto",
                choices=["auto", "pallas", "ref"])
a = ap.parse_args()

spec = TaskSpec()
model = Model(TINY)
params = model.init(jax.random.key(0))
loss, per_example, evaluate = make_task_fns(model, spec)

# 1. transferable sparse mask from pre-training-gradient sensitivity (§2.1)
pre = pretrain_batches(spec, n_batches=8, batch_size=32)
space = sensitivity_mask(lambda p, b: model.loss(p, b), params, pre,
                         density=1e-2)
print(f"mask: {space.n} / {model.n_params} params "
      f"({space.n / model.n_params:.2%} density)")

# 2. Non-IID clients (Dirichlet alpha=0.5)
train = sample_dataset(spec, 2048, seed=1)
parts = dirichlet_partition(train["label"], n_clients=a.clients, alpha=0.5)
clients = [Client(k, subset(train, p), batch_size=16)
           for k, p in enumerate(parts)]

# 3. high-frequency MEERKAT (T=1): scalar-only sync every local step
fl = FLConfig(n_clients=a.clients, local_steps=1, lr=5e-2, eps=1e-3,
              density=1e-2, zo_backend=a.zo_backend)
server = FederatedZO(loss, params, space, fl, clients, eval_fn=evaluate)

ev = sample_dataset(spec, 512, seed=2)
eval_batch = {k: np.asarray(v) for k, v in ev.items()}
m0 = evaluate(params, eval_batch)
print(f"before: acc={float(m0['acc']):.3f}")
server.run(a.rounds, eval_every=max(1, a.rounds // 3),
           eval_batch=eval_batch, verbose=True)
m = evaluate(server.params, eval_batch)
print(f"after {a.rounds} rounds: acc={float(m['acc']):.3f}  "
      f"(upload/client/round = 4 bytes)")
