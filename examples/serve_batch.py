"""Continuous-batching serving demo across architecture families — the path
the decode_32k / long_500k dry-run shapes lower.

    PYTHONPATH=src python examples/serve_batch.py

Serves reduced variants of three assigned archs (dense gemma2 with
local/global attention + softcaps, hybrid jamba with Mamba+MoE layers, and
pixtral with the vision-stub frontend) through the slot-based engine:
bucketed per-request prefill admits each prompt into a free decode slot,
one compiled step advances all active slots, and finished requests retire
early to make room for the queue.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serving.engine import ContinuousBatchingEngine

ap = argparse.ArgumentParser()
ap.add_argument("--archs", default="gemma2-27b,jamba-1.5-large-398b,"
                "pixtral-12b",
                help="comma-separated registered arch names (reduced "
                     "variants are served)")
ap.add_argument("--requests", type=int, default=5)
ap.add_argument("--max-new", type=int, default=8)
a = ap.parse_args()

rng = np.random.default_rng(0)
for name in a.archs.split(","):
    cfg = get_config(name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = ContinuousBatchingEngine(model, params, max_slots=4, S_max=96,
                                      bucket=16)
    for i in range(a.requests):
        prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(4, 20)))
        engine.submit(prompt, max_new_tokens=a.max_new)
    t0 = time.time()
    outs = engine.run()
    dt = time.time() - t0
    n = sum(len(o) for o in outs)
    s = engine.stats
    print(f"{cfg.name:32s} family={cfg.family:6s} "
          f"{model.n_params / 1e6:6.1f}M params | {len(outs)} reqs, "
          f"{n} tokens in {dt:5.1f}s ({n / dt:5.1f} tok/s, "
          f"{s['decode_steps']} steps, {s['compile_misses']} compiles)")
    print(f"  first generation: {outs[0].tolist()}")
