"""GradIP phenomenon + Virtual-Path Client Selection, visualized.

    PYTHONPATH=src python examples/vpcs_demo.py              # full demo
    PYTHONPATH=src python examples/vpcs_demo.py --steps 60   # CI smoke

The server reconstructs each client's gradient trajectory from uploaded
scalars + shared seeds (the virtual path), computes GradIP against its
pre-training gradient, and flags extreme Non-IID clients — printed here as
ASCII sparklines so the decay-vs-oscillation signature is visible.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.tiny import TINY
from repro.core import (Client, analyze_trajectory, gradip_trajectory,
                        make_local_run, pretrain_gradient_vec, round_keys,
                        sensitivity_mask)
from repro.data.corpus import pretrain_batches
from repro.data.partition import (dirichlet_partition, single_label_partition,
                                  subset)
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.models import Model

BARS = " .:-=+*#%@"


def spark(x, width=60):
    x = np.asarray(x, np.float64)
    x = np.abs(x)
    bins = np.array_split(x, width)
    m = np.array([b.mean() for b in bins])
    m = m / (m.max() + 1e-12)
    return "".join(BARS[int(v * (len(BARS) - 1))] for v in m)


ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200,
                help="calibration-phase local steps (T)")
args = ap.parse_args()

spec = TaskSpec()
model = Model(TINY)
params = model.init(jax.random.key(0))
loss, _, _ = make_task_fns(model, spec)
lm = lambda p, b: model.loss(p, b)

pre = pretrain_batches(spec, n_batches=8, batch_size=32)
space = sensitivity_mask(lm, params, pre, density=5e-2)
gp = pretrain_gradient_vec(lm, params, space, pre)

train = sample_dataset(spec, 2048, seed=1)
parts = (dirichlet_partition(train["label"], 4, alpha=5.0, seed=0)
         + single_label_partition(train["label"], 2, seed=1))
clients = [Client(k, subset(train, p), 32) for k, p in enumerate(parts)]
kinds = ["balanced"] * 4 + ["single-label"] * 2

T = args.steps
run = jax.jit(make_local_run(loss, space, eps=1e-3, lr=5e-2))
keys = round_keys(0, 0, T)
# thresholds are scale-relative: GradIP magnitudes on the tiny model are
# ~1e-2 (the paper's sigma=1 suits 1-3B models)
fl = FLConfig(vp_rho_later=3.0, vp_sigma=0.01, vp_init_steps=min(40, T // 2),
              vp_later_steps=min(40, T // 2))

print(f"GradIP over {T} local steps (server-side virtual path):\n")
for c, kind in zip(clients, kinds):
    b = {k: jnp.asarray(v) for k, v in c.next_batches(T).items()}
    _, gs = run(params, keys, b, jnp.zeros((space.n,), jnp.float32))
    ips, _, _ = gradip_trajectory(space, keys, gs, gp)
    r = analyze_trajectory(np.asarray(ips), fl)
    flag = "EARLY-STOP" if r.flagged else "          "
    print(f"client {c.cid} [{kind:12s}] {flag} rho={r.rho_later:5.2f} "
          f"|{spark(ips)}|")
print("\nflagged clients are limited to T=1 local step per round "
      "(Algorithm 1); their data is still consumed via the data pointer.")
