"""Sharded federated round: the same MEERKAT round, on a device mesh.

    PYTHONPATH=src python examples/mesh_round.py            # 2x2 host mesh
    PYTHONPATH=src python examples/mesh_round.py --mesh 4x1

Forces a host-device mesh (XLA_FLAGS, before jax import), builds a
``sharding/fl.FLShardPlan`` (parameters FSDP-sharded per
``sharding/rules.py``, the client axis over the mesh batch axes), runs
rounds both unsharded and sharded, and verifies the tentpole invariant:
**the aggregated update and every GradIP trajectory are bit-identical** —
seed-replay virtual-path reconstruction does not care how the round was
sharded (DESIGN.md §9).
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--mesh", default="2x2", help="DxM host-device mesh spec")
ap.add_argument("--rounds", type=int, default=3)
ap.add_argument("--T", type=int, default=4)
a = ap.parse_args()

from repro.launch.mesh import (host_device_flag,  # noqa: E402 — no jax
                               parse_mesh_spec)   # device state touched

n_dev = parse_mesh_spec(a.mesh).n_devices
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + host_device_flag(n_dev)).strip()

import jax  # noqa: E402  (after the XLA_FLAGS setup, by design)
import numpy as np  # noqa: E402

from repro.configs.base import FLConfig  # noqa: E402
from repro.configs.tiny import TINY  # noqa: E402
from repro.core import (Client, FederatedZO,  # noqa: E402
                        pretrain_gradient_vec, sensitivity_mask)
from repro.data.corpus import pretrain_batches  # noqa: E402
from repro.data.partition import dirichlet_partition, subset  # noqa: E402
from repro.data.synthetic import (TaskSpec, make_task_fns,  # noqa: E402
                                  sample_dataset)
from repro.models import Model  # noqa: E402
from repro.sharding import make_fl_plan  # noqa: E402

spec = TaskSpec()
model = Model(TINY)
params = model.init(jax.random.key(0))
loss, _, evaluate = make_task_fns(model, spec)
pre = pretrain_batches(spec, n_batches=4, batch_size=16)
space = sensitivity_mask(lambda p, b: model.loss(p, b), params, pre,
                         density=1e-2)
gp = pretrain_gradient_vec(lambda p, b: model.loss(p, b), params, space, pre)

train = sample_dataset(spec, 1024, seed=1)
K = 4


def make_server(plan):
    parts = dirichlet_partition(train["label"], K, alpha=0.5, seed=0)
    clients = [Client(k, subset(train, p), 16) for k, p in enumerate(parts)]
    fl = FLConfig(n_clients=K, local_steps=a.T, lr=5e-2, eps=1e-3,
                  zo_backend="ref")  # the mesh route's backend — see DESIGN §9
    return FederatedZO(loss, params, space, fl, clients, plan=plan)


print(f"single-device reference ({a.rounds} rounds, T={a.T}, K={K}) ...")
ref = make_server(None)
for _ in range(a.rounds):
    ref.run_round(gp_vec=gp)

plan = make_fl_plan(spec=a.mesh)  # rule="fsdp": bit-exact by design
print(f"mesh {a.mesh}: {plan.mesh_cfg.n_devices} devices, "
      f"params {plan.rule}-sharded, client axis over {plan.batch_axes}")
srv = make_server(plan)
for _ in range(a.rounds):
    srv.run_round(gp_vec=gp)

flat = lambda t: np.concatenate([np.asarray(x).ravel()
                                 for x in jax.tree.leaves(t)])
bit_params = bool(np.array_equal(flat(ref.params), flat(srv.params)))
bit_gradip = all(
    np.array_equal(np.stack(ref.gradip_log[c]), np.stack(srv.gradip_log[c]))
    for c in ref.gradip_log)
print(f"aggregated params bit-identical: {bit_params}")
print(f"GradIP trajectories bit-identical: {bit_gradip}")
print(f"comm per client per round: up 4*T = {4 * a.T} B "
      f"(mesh-invariant: {ref.comm.up_bytes == srv.comm.up_bytes})")
if not (bit_params and bit_gradip):
    sys.exit(1)
print("sharded round == single-device round, bit for bit.")
