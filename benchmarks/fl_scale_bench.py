"""Federated-round scaling benchmark: round time + comm bytes vs
client count x mesh shape (ISSUE 5 tentpole; writes
``runs/bench/BENCH_fl_scale.json``).

For each (arch in {tiny, qwen3-4b-reduced}) x (client count) x (mesh
spec), a **subprocess** (XLA must learn the forced host-device count
before jax initializes) runs ``FederatedZO`` rounds under the
``sharding/fl.FLShardPlan`` mesh route and reports:

* ``round_s``          — median wall time of a full federated round,
* ``comm_up/down``     — FL protocol bytes per round (``CommLog``; must be
  mesh-invariant — gated),
* ``collectives``      — per-device intra-mesh collective bytes of the
  compiled client-group HLO (``launch/hlo_tools.collective_bytes``): the
  cost sharding *adds* (ZeRO-3 weight gather) next to the scalars the FL
  protocol moves — the paper's 1000x saving is only meaningful when both
  are visible,
* the production 16x16 mesh (256 host devices) as a **dry-run row**:
  lower + compile + collective extraction only, execution skipped
  (matching ``launch/dryrun.py`` semantics).

``zo_backend="ref"`` everywhere so mesh shapes compare the same per-step
route (the fused-vs-ref axis is BENCH_zo_step's job).

Usage:
  PYTHONPATH=src python -m benchmarks.fl_scale_bench           # full grid
  PYTHONPATH=src python -m benchmarks.fl_scale_bench --smoke   # CI subset
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

RUNS_DIR = os.path.join(os.path.dirname(__file__), "..", "runs", "bench")
ARCHS = ("tiny", "qwen3-4b")
EXEC_MESHES = ("none", "1x1", "2x2")
DRYRUN_MESH = "16x16"


def mesh_devices(spec: str) -> int:
    if spec == "none":
        return 1
    from repro.launch.mesh import parse_mesh_spec  # no jax device state
    return parse_mesh_spec(spec).n_devices


# --------------------------------------------------------------------------
# worker: one (arch, clients, mesh) cell, run in a fresh process
# --------------------------------------------------------------------------

def worker(a) -> dict:
    import jax
    import jax.numpy as jnp  # noqa: F401
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import FLConfig
    from repro.configs.tiny import TINY
    from repro.core import Client, FederatedZO, random_mask, round_keys
    from repro.data.partition import dirichlet_partition, subset
    from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
    from repro.launch.hlo_tools import COLLECTIVE_FACTOR, collective_bytes
    from repro.models import Model
    from repro.sharding.fl import make_fl_plan

    cfg = TINY if a.arch == "tiny" else get_config(a.arch).reduced()
    spec = TaskSpec(vocab=min(cfg.vocab, 512), seq_len=16)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    loss, _, _ = make_task_fns(model, spec)
    space = random_mask(params, density=1e-2, seed=3, balanced=False)

    train = sample_dataset(spec, max(2048, a.clients * a.T * 16), seed=1)
    parts = dirichlet_partition(train["label"], a.clients, 0.5, seed=0)
    clients = [Client(k, subset(train, p), 16) for k, p in enumerate(parts)]
    plan = (None if a.mesh == "none"
            else make_fl_plan(spec=a.mesh, rule=a.rule))
    fl = FLConfig(n_clients=a.clients, local_steps=a.T, lr=5e-2, eps=1e-3,
                  seed=0, zo_backend="ref")
    srv = FederatedZO(loss, params, space, fl, clients, plan=plan)

    rec = {"arch": cfg.name, "mesh": a.mesh, "rule": a.rule,
           "n_devices": 1 if plan is None else plan.mesh_cfg.n_devices,
           "clients": a.clients, "T": a.T, "space_n": space.n,
           "n_params": model.n_params,
           "mode": "compile-only" if a.compile_only else "exec"}

    if not a.compile_only:
        # warm every jit cache (client group + virtual-path recon) with a
        # real round, then time
        srv.run_round()
        times = []
        for _ in range(a.reps):
            up0, down0 = srv.comm.up_bytes, srv.comm.down_bytes
            t0 = time.time()
            srv.run_round()
            times.append(time.time() - t0)
        rec["round_s"] = round(float(np.median(times)), 4)
        rec["comm_up_bytes_per_round"] = srv.comm.up_bytes - up0
        rec["comm_down_bytes_per_round"] = srv.comm.down_bytes - down0

    # collective extraction needs the Compiled object, which only the AOT
    # lower().compile() path exposes — one extra compile per cell, paid
    # after the timing loop (and the *only* compile in compile-only mode,
    # the 16x16 dry-run rows)
    batches = srv._stack([c.next_batches(a.T) for c in clients])
    for c in clients:
        c.ptr = 0
    grp = srv._batch_run_for(a.T, a.clients, template_batches=batches)
    keys = round_keys(fl.seed, 0, a.T)
    keys_d, batches_d = srv._place_group(keys, batches, a.clients)
    t0 = time.time()
    compiled = grp.lower(srv.params, keys_d, batches_d).compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    coll = collective_bytes(compiled.as_text())
    rec["collectives"] = coll
    rec["collective_wire_bytes_per_device"] = sum(
        COLLECTIVE_FACTOR[op] * b for op, b in coll.items())
    rec["ok"] = True
    return rec


# --------------------------------------------------------------------------
# parent: spawn one subprocess per cell with the right XLA_FLAGS
# --------------------------------------------------------------------------

def run_cell(arch: str, clients: int, mesh: str, rule: str, T: int,
             reps: int, compile_only: bool) -> dict:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    n = mesh_devices(mesh)
    if n > 1:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={n}"
                            ).strip()
    out = tempfile.NamedTemporaryFile(suffix=".json", delete=False)
    out.close()
    cmd = [sys.executable, "-m", "benchmarks.fl_scale_bench", "--worker",
           "--arch", arch, "--clients", str(clients), "--mesh", mesh,
           "--rule", rule, "--T", str(T), "--reps", str(reps),
           "--out-json", out.name]
    if compile_only:
        cmd.append("--compile-only")
    t0 = time.time()
    rec = {"arch": arch, "mesh": mesh, "rule": rule, "clients": clients,
           "T": T, "ok": False}
    try:
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=3600)
        if proc.returncode == 0 and os.path.getsize(out.name):
            with open(out.name) as f:
                rec = json.load(f)
        else:
            rec["error"] = (proc.stderr or proc.stdout)[-2000:]
    except subprocess.TimeoutExpired:
        rec["error"] = "timeout (3600s)"  # record the cell, keep the grid
    finally:
        rec["wall_s"] = round(time.time() - t0, 1)
        os.unlink(out.name)
    status = "ok " if rec.get("ok") else "FAIL"
    print(f"[{status}] {arch} K={clients} mesh={mesh} "
          f"{'(compile-only) ' if compile_only else ''}"
          f"round={rec.get('round_s', '-')}s wall={rec['wall_s']}s",
          flush=True)
    return rec


def gates(rows) -> dict:
    """comm_invariant: FL protocol bytes identical across mesh shapes for
    the same (arch, clients, T) cell — and actually *compared*: every
    cell must have succeeded on >= 2 distinct mesh shapes, else the gate
    fails rather than passing vacuously.  all_ok: every cell ran."""
    comm, meshes = {}, {}
    for r in rows:
        if r.get("mode") == "exec" and r.get("ok"):
            cell = (r["arch"], r["clients"], r["T"])
            comm.setdefault(cell, set()).add(
                (r["comm_up_bytes_per_round"],
                 r["comm_down_bytes_per_round"]))
            meshes.setdefault(cell, set()).add(r["mesh"])
    compared = bool(comm) and all(len(m) >= 2 for m in meshes.values())
    return {"comm_invariant_across_mesh":
            compared and all(len(v) == 1 for v in comm.values()),
            "all_ok": all(r.get("ok") for r in rows) and bool(rows)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--mesh", default="none")
    ap.add_argument("--rule", default="fsdp")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--T", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--compile-only", action="store_true")
    ap.add_argument("--out-json", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset; writes BENCH_fl_scale_smoke.json")
    a = ap.parse_args()

    if a.worker:
        rec = worker(a)
        with open(a.out_json, "w") as f:
            json.dump(rec, f, indent=1)
        return

    if a.smoke:
        # CI vehicle: one executed mesh + the 256-host-device production
        # mesh as a compile-only dry-run (launch/dryrun.py semantics)
        cells = [("tiny", 4, m, False) for m in ("none", "2x2")]
        cells += [("tiny", 256, DRYRUN_MESH, True)]
        reps = 1
    else:
        cells = [(arch, K, m, False)
                 for arch in ARCHS for K in (4, 8) for m in EXEC_MESHES]
        # production-mesh dry-run rows: 256 host devices, compile only
        cells += [(arch, 256, DRYRUN_MESH, True) for arch in ARCHS]
        reps = 3
    rows = [run_cell(arch, K, mesh, a.rule, a.T, reps, co)
            for arch, K, mesh, co in cells]
    result = {"bench": "fl_scale", "rule": a.rule, "T": a.T,
              "zo_backend": "ref", "rows": rows, "gates": gates(rows)}
    os.makedirs(RUNS_DIR, exist_ok=True)
    name = "BENCH_fl_scale_smoke" if a.smoke else "BENCH_fl_scale"
    path = os.path.join(RUNS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"gates: {result['gates']}")
    print("wrote", os.path.abspath(path))


if __name__ == "__main__":
    main()
