"""Federated-round scaling benchmark: round time + comm bytes vs
client count x mesh shape x fleet knobs (ISSUE 5 tentpole, grown to the
fleet scale of ISSUE 10; writes ``runs/bench/BENCH_fl_scale.json``).

For each (arch in {tiny, qwen3-4b-reduced}) x (client count) x (mesh
spec), a **subprocess** (XLA must learn the forced host-device count
before jax initializes) runs ``FederatedZO`` rounds under the
``sharding/fl.FLShardPlan`` mesh route and reports:

* ``round_s``          — median wall time of a full federated round,
* ``comm_up/down``     — FL protocol bytes per round (``CommLog``; must be
  mesh-invariant — gated),
* ``collectives``      — per-device intra-mesh collective bytes of the
  compiled client-group HLO (``launch/hlo_tools.collective_bytes``): the
  cost sharding *adds* (ZeRO-3 weight gather) next to the scalars the FL
  protocol moves — the paper's 1000x saving is only meaningful when both
  are visible,
* the production 16x16 mesh (256 host devices) as a **dry-run row**:
  lower + compile + collective extraction only, execution skipped
  (matching ``launch/dryrun.py`` semantics).

**Fleet rows** (``--cohort``/``--quantize``; DESIGN.md §12) scale the
client count K into the thousands with a fixed sampled cohort ``m`` and
a quantized uplink, at T=1 (Alg. 3 high-frequency downlink — seeds +
scalars, independent of model size).  Executed at K in {64, 512};
K=4096 runs compile-only with *analytic* per-round comm bytes (the
protocol traffic is a closed form of (m, T, n_dirs, codec) — gated to
match the measured rows at smaller K).  Fleet gates:

* ``comm_bytes_scale_sublinear_in_K`` — per-round protocol bytes grow
  strictly slower than K at fixed cohort (they are constant),
* ``uplink_model_independent``        — fleet uplink+downlink bytes are
  identical across architectures (seeds + scalars only),
* ``quant_uplink_saves_bytes``        — int8 rows bill less uplink than
  the f32 rows of the same cell,
* ``round_time_sublinear_in_K``       — wall-clock per round grows
  sublinearly in K at fixed cohort size.

``zo_backend="ref"`` everywhere so mesh shapes compare the same per-step
route (the fused-vs-ref axis is BENCH_zo_step's job).

Usage:
  PYTHONPATH=src python -m benchmarks.fl_scale_bench              # full grid
  PYTHONPATH=src python -m benchmarks.fl_scale_bench --smoke      # CI subset
  PYTHONPATH=src python -m benchmarks.fl_scale_bench --fleet-only # merge
      just the fleet rows into an existing BENCH_fl_scale.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

RUNS_DIR = os.path.join(os.path.dirname(__file__), "..", "runs", "bench")
ARCHS = ("tiny", "qwen3-4b")
EXEC_MESHES = ("none", "1x1", "2x2")
DRYRUN_MESH = "16x16"
FLEET_COHORT = 16

# the fleet axis: (arch, K, quantize, compile_only) at T=1, mesh none
FLEET_CELLS = (
    ("tiny", 64, "none", False),
    ("tiny", 64, "int8", False),
    ("tiny", 512, "int8", False),
    ("qwen3-4b", 64, "int8", False),
    ("tiny", 4096, "int8", True),
    ("qwen3-4b", 4096, "int8", True),
)


def mesh_devices(spec: str) -> int:
    if spec == "none":
        return 1
    from repro.launch.mesh import parse_mesh_spec  # no jax device state
    return parse_mesh_spec(spec).n_devices


# --------------------------------------------------------------------------
# worker: one (arch, clients, mesh, cohort, quantize) cell, fresh process
# --------------------------------------------------------------------------

def worker(a) -> dict:
    import jax
    import jax.numpy as jnp  # noqa: F401
    import numpy as np

    from repro.checkpoint.state import server_state_sizes
    from repro.configs import get_config
    from repro.configs.base import FLConfig
    from repro.configs.tiny import TINY
    from repro.core import (Client, ClientSampler, FederatedZO, make_codec,
                            random_mask, round_keys)
    from repro.data.partition import dirichlet_partition, subset
    from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
    from repro.launch.hlo_tools import COLLECTIVE_FACTOR, collective_bytes
    from repro.models import Model
    from repro.sharding.fl import make_fl_plan

    cfg = TINY if a.arch == "tiny" else get_config(a.arch).reduced()
    spec = TaskSpec(vocab=min(cfg.vocab, 512), seq_len=16)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    loss, _, _ = make_task_fns(model, spec)
    space = random_mask(params, density=1e-2, seed=3, balanced=False)

    fleet = 0 < a.cohort < a.clients
    m = a.cohort if fleet else a.clients
    # compile-only fleet cells only ever run the m-wide group program, so
    # materializing thousands of client datasets would be pure waste: the
    # K axis enters through the *analytic* protocol bytes below
    n_build = m if (fleet and a.compile_only) else a.clients
    train = sample_dataset(spec, max(2048, n_build * a.T * 16), seed=1)
    parts = dirichlet_partition(train["label"], n_build, 0.5, seed=0)
    clients = [Client(k, subset(train, p), a.batch)
               for k, p in enumerate(parts)]
    plan = (None if a.mesh == "none"
            else make_fl_plan(spec=a.mesh, rule=a.rule))
    fl = FLConfig(n_clients=a.clients, local_steps=a.T, lr=5e-2, eps=1e-3,
                  seed=0, zo_backend="ref", batch_size=a.batch,
                  quantize=a.quantize)
    sampler = (ClientSampler(range(a.clients), m=m, seed=0)
               if fleet and not a.compile_only else None)
    srv = FederatedZO(loss, params, space, fl, clients, plan=plan,
                      sampler=sampler)

    rec = {"arch": cfg.name, "mesh": a.mesh, "rule": a.rule,
           "n_devices": 1 if plan is None else plan.mesh_cfg.n_devices,
           "clients": a.clients, "T": a.T, "space_n": space.n,
           "n_params": model.n_params,
           "cohort": a.cohort, "quantize": a.quantize,
           "mode": "compile-only" if a.compile_only else "exec"}

    if not a.compile_only:
        # warm every jit cache (client group + virtual-path recon) with a
        # real round, then time
        srv.run_round()
        times = []
        for _ in range(a.reps):
            up0, down0 = srv.comm.up_bytes, srv.comm.down_bytes
            t0 = time.time()
            srv.run_round()
            times.append(time.time() - t0)
        rec["round_s"] = round(float(np.median(times)), 4)
        rec["comm_up_bytes_per_round"] = srv.comm.up_bytes - up0
        rec["comm_down_bytes_per_round"] = srv.comm.down_bytes - down0
        sizes = server_state_sizes(srv)
        rec["server_model_state_bytes"] = sizes["model_state_bytes"]
        rec["server_per_client_state_bytes"] = \
            sizes["per_client_state_bytes"]
        if a.quantize != "none":
            # quantization error on real round scalars: an identity-twin
            # server (same seeds, same cohort draws) produces the
            # unquantized uploads; roundtrip them through this cell's codec
            twin = FederatedZO(
                loss, params, space,
                FLConfig(n_clients=a.clients, local_steps=a.T, lr=5e-2,
                         eps=1e-3, seed=0, zo_backend="ref",
                         batch_size=a.batch),
                clients, plan=plan,
                sampler=(ClientSampler(range(a.clients), m=m, seed=0)
                         if fleet else None))
            gs = twin.run_round()
            codec = make_codec(a.quantize)
            g = np.concatenate([np.asarray(v, np.float32).ravel()
                                for v in gs.values()])
            dec = np.concatenate(
                [codec.decode(codec.encode(np.asarray(v))).ravel()
                 for v in gs.values()])
            rec["quant_rel_err"] = round(
                float(np.linalg.norm(dec - g)
                      / (np.linalg.norm(g) + 1e-30)), 6)
    else:
        # analytic protocol bytes: uplink = m encoded scalar blocks,
        # downlink = m seed+scalar packets (T=1 high-freq) — a closed
        # form of (m, T, n_dirs, codec), gated against the measured
        # rows at smaller K
        n_scalars = a.T * getattr(fl, "n_dirs", 1)
        rec["comm_up_bytes_per_round"] = m * srv.codec.nbytes(n_scalars)
        rec["comm_down_bytes_per_round"] = m * srv._down_bytes(a.T)
        rec["comm_analytic"] = True

    # collective extraction needs the Compiled object, which only the AOT
    # lower().compile() path exposes — one extra compile per cell, paid
    # after the timing loop (and the *only* compile in compile-only mode,
    # the 16x16 dry-run and K=4096 fleet rows).  Fleet cells probe the
    # m-wide cohort program on the first m clients — the sampler's RNG
    # must not advance outside run_round.
    probe = clients[:m]
    batches = srv._stack([c.next_batches(a.T) for c in probe])
    for c in probe:
        c.ptr = 0
    grp = srv._batch_run_for(a.T, m, template_batches=batches)
    keys = round_keys(fl.seed, 0, a.T)
    keys_d, batches_d = srv._place_group(keys, batches, m)
    t0 = time.time()
    compiled = grp.lower(srv.params, keys_d, batches_d).compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    coll = collective_bytes(compiled.as_text())
    rec["collectives"] = coll
    rec["collective_wire_bytes_per_device"] = sum(
        COLLECTIVE_FACTOR[op] * b for op, b in coll.items())
    rec["ok"] = True
    return rec


# --------------------------------------------------------------------------
# parent: spawn one subprocess per cell with the right XLA_FLAGS
# --------------------------------------------------------------------------

def run_cell(arch: str, clients: int, mesh: str, rule: str, T: int,
             reps: int, compile_only: bool, cohort: int = 0,
             quantize: str = "none", batch: int = 16) -> dict:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    n = mesh_devices(mesh)
    if n > 1:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={n}"
                            ).strip()
    out = tempfile.NamedTemporaryFile(suffix=".json", delete=False)
    out.close()
    cmd = [sys.executable, "-m", "benchmarks.fl_scale_bench", "--worker",
           "--arch", arch, "--clients", str(clients), "--mesh", mesh,
           "--rule", rule, "--T", str(T), "--reps", str(reps),
           "--cohort", str(cohort), "--quantize", quantize,
           "--batch", str(batch), "--out-json", out.name]
    if compile_only:
        cmd.append("--compile-only")
    t0 = time.time()
    rec = {"arch": arch, "mesh": mesh, "rule": rule, "clients": clients,
           "T": T, "cohort": cohort, "quantize": quantize, "ok": False}
    try:
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=3600)
        if proc.returncode == 0 and os.path.getsize(out.name):
            with open(out.name) as f:
                rec = json.load(f)
        else:
            rec["error"] = (proc.stderr or proc.stdout)[-2000:]
    except subprocess.TimeoutExpired:
        rec["error"] = "timeout (3600s)"  # record the cell, keep the grid
    finally:
        rec["wall_s"] = round(time.time() - t0, 1)
        os.unlink(out.name)
    status = "ok " if rec.get("ok") else "FAIL"
    fleet = f"m={cohort} {quantize} " if cohort else ""
    print(f"[{status}] {arch} K={clients} mesh={mesh} {fleet}"
          f"{'(compile-only) ' if compile_only else ''}"
          f"round={rec.get('round_s', '-')}s wall={rec['wall_s']}s",
          flush=True)
    return rec


def _fleet_key(r) -> tuple:
    return (r["arch"], r.get("T"), r.get("cohort", 0),
            r.get("quantize", "none"))


def gates(rows) -> dict:
    """Protocol gates over the result grid.  Gates that have nothing to
    compare in this grid report ``None`` (not compared) rather than
    passing vacuously; ``comm_invariant_across_mesh`` requires at least
    one cell measured on >= 2 distinct meshes."""
    ok_rows = [r for r in rows if r.get("ok")]

    # mesh invariance: same (arch, K, T, cohort, quantize) cell, >= 2
    # meshes, identical protocol bytes — fleet rows run one mesh and are
    # simply not compared here
    per_cell, meshes = {}, {}
    for r in ok_rows:
        if r.get("mode") == "exec" and "comm_up_bytes_per_round" in r:
            cell = (r["arch"], r["clients"], r.get("T"),
                    r.get("cohort", 0), r.get("quantize", "none"))
            per_cell.setdefault(cell, set()).add(
                (r["comm_up_bytes_per_round"],
                 r["comm_down_bytes_per_round"]))
            meshes.setdefault(cell, set()).add(r["mesh"])
    multi = [c for c, ms in meshes.items() if len(ms) >= 2]
    comm_invariant = (all(len(per_cell[c]) == 1 for c in multi)
                      if multi else None)

    # fleet gates: group fleet rows (cohort > 0) by everything but K
    fleet = [r for r in ok_rows if r.get("cohort", 0) > 0
             and "comm_up_bytes_per_round" in r]
    by_cell = {}
    for r in fleet:
        by_cell.setdefault(_fleet_key(r), {})[r["clients"]] = r

    def tot(r):
        return (r["comm_up_bytes_per_round"]
                + r["comm_down_bytes_per_round"])

    sub_bytes, sub_time = [], []
    for ks in by_cell.values():
        Ks = sorted(ks)
        for k1, k2 in zip(Ks, Ks[1:]):
            a, b = ks[k1], ks[k2]
            sub_bytes.append(tot(b) * k1 < tot(a) * k2)  # strictly sublinear
            if "round_s" in a and "round_s" in b:
                sub_time.append(b["round_s"] * k1 < a["round_s"] * k2)
    comm_sublinear = all(sub_bytes) if sub_bytes else None
    time_sublinear = all(sub_time) if sub_time else None

    # model independence: same (K, T, cohort, quantize), >= 2 archs,
    # identical protocol bytes (seeds + scalars carry no model dims)
    by_arch = {}
    for r in fleet:
        key = (r["clients"], r.get("T"), r.get("cohort", 0),
               r.get("quantize", "none"))
        by_arch.setdefault(key, {})[r["arch"]] = (
            r["comm_up_bytes_per_round"], r["comm_down_bytes_per_round"])
    multi_arch = [v for v in by_arch.values() if len(v) >= 2]
    model_indep = (all(len(set(v.values())) == 1 for v in multi_arch)
                   if multi_arch else None)

    # quantization savings: same (arch, K, T, cohort), int vs none
    savings = []
    by_quant = {}
    for r in fleet:
        key = (r["arch"], r["clients"], r.get("T"), r.get("cohort", 0))
        by_quant.setdefault(key, {})[r.get("quantize", "none")] = \
            r["comm_up_bytes_per_round"]
    for v in by_quant.values():
        if "none" in v:
            for q, up in v.items():
                if q != "none":
                    savings.append(up < v["none"])
    quant_saves = all(savings) if savings else None

    return {"comm_invariant_across_mesh": comm_invariant,
            "comm_bytes_scale_sublinear_in_K": comm_sublinear,
            "round_time_sublinear_in_K": time_sublinear,
            "uplink_model_independent": model_indep,
            "quant_uplink_saves_bytes": quant_saves,
            "all_ok": all(r.get("ok") for r in rows) and bool(rows)}


def fleet_cells(smoke: bool):
    """Fleet-axis cells: (arch, K, mesh, compile_only, T, cohort, quant)."""
    if smoke:
        picks = (("tiny", 64, "int8", False), ("tiny", 4096, "int8", True))
    else:
        picks = FLEET_CELLS
    return [(arch, K, "none", co, 1, FLEET_COHORT, q)
            for arch, K, q, co in picks]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--arch", default="tiny")
    ap.add_argument("--mesh", default="none")
    ap.add_argument("--rule", default="fsdp")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--T", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--cohort", type=int, default=0,
                    help="fleet mode: fixed sampled cohort size (0 = every "
                         "client participates)")
    ap.add_argument("--quantize", default="none",
                    help="uplink codec for the fleet rows "
                         "(none|int8|int4[-nearest])")
    ap.add_argument("--compile-only", action="store_true")
    ap.add_argument("--out-json", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset; writes BENCH_fl_scale_smoke.json")
    ap.add_argument("--fleet-only", action="store_true",
                    help="run only the fleet-axis cells and merge them "
                         "into the existing BENCH_fl_scale.json")
    a = ap.parse_args()

    if a.worker:
        rec = worker(a)
        with open(a.out_json, "w") as f:
            json.dump(rec, f, indent=1)
        return

    if a.smoke:
        # CI vehicle: one executed mesh pair + the 256-host-device
        # production mesh as a compile-only dry-run + the fleet axis
        # (sampled cohort, quantized uplink, K up to 4096 analytic)
        cells = [("tiny", 4, m, False, a.T, 0, "none")
                 for m in ("none", "2x2")]
        cells += [("tiny", 256, DRYRUN_MESH, True, a.T, 0, "none")]
        cells += fleet_cells(smoke=True)
        reps = 1
    elif a.fleet_only:
        cells = fleet_cells(smoke=False)
        reps = 3
    else:
        cells = [(arch, K, m, False, a.T, 0, "none")
                 for arch in ARCHS for K in (4, 8) for m in EXEC_MESHES]
        # production-mesh dry-run rows: 256 host devices, compile only
        cells += [(arch, 256, DRYRUN_MESH, True, a.T, 0, "none")
                  for arch in ARCHS]
        cells += fleet_cells(smoke=False)
        reps = 3
    rows = [run_cell(arch, K, mesh, a.rule, T, reps, co, cohort=m,
                     quantize=q, batch=a.batch)
            for arch, K, mesh, co, T, m, q in cells]

    os.makedirs(RUNS_DIR, exist_ok=True)
    name = "BENCH_fl_scale_smoke" if a.smoke else "BENCH_fl_scale"
    path = os.path.join(RUNS_DIR, f"{name}.json")
    if a.fleet_only and os.path.exists(path):
        with open(path) as f:
            prior = json.load(f)
        keep = [r for r in prior.get("rows", [])
                if r.get("cohort", 0) == 0]  # refresh the fleet rows
        rows = keep + rows
    result = {"bench": "fl_scale", "rule": a.rule, "T": a.T,
              "zo_backend": "ref", "fleet_cohort": FLEET_COHORT,
              "rows": rows, "gates": gates(rows)}
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"gates: {result['gates']}")
    print("wrote", os.path.abspath(path))


if __name__ == "__main__":
    main()
