"""Paper Table 11: MEERKAT vs DeComFL at the same communication frequency.

DeComFL (Li et al., 2024 [16]) achieves *dimension-free* communication for
full-parameter federated ZO: with shared per-step seeds, the round update
is sum_t mean_k(g_k^t) * z_t, so the server can broadcast the T averaged
scalars instead of model weights and clients replay them.  In this
framework that is exactly ``FederatedZO(space=DenseSpace, high_freq=True)``
— the same scalar-only uplink/downlink as MEERKAT, but perturbing all d
parameters.

Claims checked:
* communication per round per client is scalar-only for BOTH methods
  (4T up / 4T+8 down) — DeComFL's contribution reproduced;
* MEERKAT still outperforms DeComFL in accuracy at equal T — the paper's
  point that sparsity helps *beyond* communication (estimator variance and
  lr-stability scale with the perturbed-coordinate count).
"""
from __future__ import annotations

import argparse

from benchmarks import common as C
from repro.configs.base import FLConfig
from repro.core import FederatedZO


def run(quick: bool = True, seed: int = 0) -> dict:
    Ts = [1, 10] if quick else [1, 10, 30]
    budget = 400
    prob = C.build_problem(seed=seed)
    rows = []
    for T in Ts:
        rounds = max(1, budget // T)
        for name, method, lr, high_freq in [
                ("decomfl", "full", 2e-3, True),
                ("meerkat", "meerkat", 1e-1 if T > 1 else 5e-2, True)]:
            space = C.make_space(prob, method, density=C.DENSITY, seed=seed)
            fl = FLConfig(n_clients=8, local_steps=T, lr=lr, eps=C.ZO_EPS,
                          density=C.DENSITY, seed=seed, batch_size=C.BATCH)
            clients = C.make_clients(prob, 8, "dirichlet", alpha=0.5,
                                     seed=seed)
            srv = FederatedZO(prob.loss, prob.params, space, fl, clients,
                              eval_fn=prob.evaluate, high_freq=high_freq)
            (_, dt) = C.timed(srv.run, rounds)
            m = C.final_metrics(srv, prob)
            per_client = 8 * rounds
            rows.append(dict(
                method=name, T=T, rounds=rounds, acc=m["acc"],
                loss=m["loss"],
                up_bytes_round=srv.comm.up_bytes / per_client,
                down_bytes_round=srv.comm.down_bytes / per_client,
                wall_s=round(dt, 1)))
            print(f"  T={T:3d} {name:8s} acc={m['acc']:.3f} "
                  f"up={rows[-1]['up_bytes_round']:.0f}B "
                  f"down={rows[-1]['down_bytes_round']:.0f}B ({dt:.0f}s)")
    acc = {(r["method"], r["T"]): r["acc"] for r in rows}
    scalar_comm = all(r["down_bytes_round"] <= 4 * r["T"] + 8 for r in rows)
    return {"table": "table11_decomfl", "rows": rows,
            "claim_scalar_only_comm_both": bool(scalar_comm),
            "claim_meerkat_beats_decomfl": bool(all(
                acc[("meerkat", T)] > acc[("decomfl", T)] for T in Ts))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("table11_decomfl", res))


if __name__ == "__main__":
    main()
