"""Forward-attention backend benchmark: dense vs online vs pallas.

Times the two hot paths the ISSUE-4 dispatch covers, per arch (tiny and the
DESIGN.md §7 scale-substituted qwen3-4b reduced()) and per sequence length
S in {256, 1024, 2048}:

* ``prefill`` — ``models/decode.prefill`` (right-padded, per-row lengths),
  the serving admission path;
* ``zo_step`` — one end-to-end T=1 MEERKAT train step
  (``core/fl_step.make_fl_train_step``), i.e. 2*n_dirs full forwards at
  sequence length S — where the attention forward dominates (Eq. 1).

Every row also checks three-way output parity and, for the blockwise
routes, the structural guarantee that no [S, S]-shaped intermediate exists
in the jaxpr (the checker that also runs in tests/test_attn_backends.py),
and carries achieved-GFLOP/s + MFU columns per backend computed against the
``launch/roofline.py`` analytic FLOPs model and per-platform peak (both
fail loudly when the model does not cover an arch or platform).
``--autotune`` ensures ``kernels.autotune`` table entries for each
(arch, S) key before timing, so the pallas rows launch with measured-best
blocks and "auto" resolvers pick the measured-fastest route.

Writes runs/bench/BENCH_attn.json.  CPU wall times validate the *structure*
(the pallas rows run the kernel in interpret mode); the [S, S]-free jaxpr
and the HBM-traffic argument (DESIGN.md §perf) are what transfer to TPU.

``--smoke`` runs the tiny arch at S=256 only (CI).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.analysis import check_no_dense_intermediates
from repro.configs import get_config
from repro.configs.tiny import TINY
from repro.models import Model
from repro.models.transformer import ShardCtx, lm_loss

BACKENDS = ("dense", "online", "pallas")


def _t_min_group(fns: dict, argfn, reps: int = 3) -> dict:
    """Interleaved best-of-reps (the microbench protocol)."""
    for fn in fns.values():
        jax.block_until_ready(fn(*argfn()))  # compile
    best = {name: float("inf") for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            args = argfn()
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def _tree_max_err(a, b):
    return max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                     - y.astype(jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def bench_row(cfg, S: int, seed: int, reps: int,
              autotune: bool = False) -> dict:
    B = 2
    if autotune:
        # measure-or-reuse the (block_q, block_k) winner for this key
        # before timing: the pallas rows then launch with tuned blocks,
        # and "auto" resolvers pick the measured-fastest route
        from repro.kernels import autotune as AT
        hd, G = cfg.resolved_head_dim, cfg.n_heads // cfg.n_kv_heads
        entry, measured = AT.ensure("fwd", S, hd, G,
                                    kv_heads=cfg.n_kv_heads, reps=reps)
        print(f"  autotune fwd S={S} hd={hd} G={G}: {entry['route']} "
              f"bq={entry['block_q']} bk={entry['block_k']} "
              f"[{'measured' if measured else 'cached'}]")
    models = {be: Model(cfg, ctx=ShardCtx(attn_backend=be))
              for be in BACKENDS}
    params = models["dense"].init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)
    lengths = jnp.asarray([S, max(1, S * 3 // 4)], jnp.int32)
    batch = {"tokens": toks}

    # ---- prefill (right-padded, per-row lengths) ----
    pf = {be: jax.jit(lambda p, b, l, m=m: m.prefill(p, b, S_max=S,
                                                     lengths=l))
          for be, m in models.items()}
    outs = {be: pf[be](params, batch, lengths) for be in BACKENDS}
    pf_err = {be: _tree_max_err(outs[be], outs["dense"])
              for be in ("online", "pallas")}
    pf_ms = _t_min_group(
        {be: pf[be] for be in BACKENDS},
        lambda: (params, batch, lengths), reps=reps)

    # ---- e2e ZO train step (2 forwards at S, Eq. 1) ----
    from repro.core import random_mask
    from repro.core.fl_step import make_fl_train_step
    space = random_mask(params, density=1e-3, seed=seed, balanced=False)
    steps, souts = {}, {}
    for be in BACKENDS:
        ctx = models[be].ctx
        per_ex = (lambda p, b, c=ctx: lm_loss(p, b, cfg, c,
                                              per_example=True))
        steps[be] = jax.jit(make_fl_train_step(
            per_ex, space, eps=1e-3, lr=1e-2, n_clients=B))
        souts[be] = steps[be](params, jax.random.key(seed + 1), batch)
    zo_err = {be: float(jnp.max(jnp.abs(souts[be][1] - souts["dense"][1])))
              for be in ("online", "pallas")}
    zo_ms = _t_min_group(
        steps, lambda: (params, jax.random.key(seed + 1), batch), reps=reps)

    # ---- structural check: blockwise attention stays [S, S]-free ----
    # (checked at the attention op, where S exceeds every non-sequence dim;
    # the model-level proof at S > vocab runs in tests/test_attn_backends)
    from repro.models import layers as L
    hd = cfg.resolved_head_dim
    q = jax.ShapeDtypeStruct((B, S, cfg.n_heads, hd), jnp.float32)
    kv = jax.ShapeDtypeStruct((B, S, cfg.n_kv_heads, hd), jnp.float32)
    no_ss = {}
    for be in ("online", "pallas"):
        jx = jax.make_jaxpr(lambda q, k, v, b=be: L.forward_attention(
            q, k, v, cfg, None, backend=b))(q, kv, kv)
        no_ss[be] = not check_no_dense_intermediates(jx, S)

    tol = 5e-2  # ZO g-scalars difference; prefill logits are tighter
    parity_ok = (all(e < 1e-2 for e in pf_err.values())
                 and all(e < tol for e in zo_err.values())
                 and all(no_ss.values()))

    # ---- achieved FLOP/s + MFU against the roofline FLOPs model ----
    # (C.roofline_flops / C.mfu raise rather than emit null when the
    # model or the platform peak is missing for this arch)
    from repro.kernels.autotune import platform_key
    from repro.launch.roofline import host_peak_flops
    peak = host_peak_flops()
    flops = {"prefill": C.roofline_flops(cfg, step="prefill", B=B, S=S),
             "zo_step": C.roofline_flops(cfg, step="zo_step", B=B, S=S)}
    ms = {"prefill": pf_ms, "zo_step": zo_ms}
    gflops = {path: {be: round(flops[path] / ms[path][be] / 1e9, 3)
                     for be in BACKENDS} for path in flops}
    mfu = {path: {be: round(C.mfu(flops[path], ms[path][be], peak), 6)
                  for be in BACKENDS} for path in flops}

    row = dict(
        arch=cfg.name, S=S,
        prefill_ms={be: round(pf_ms[be] * 1e3, 2) for be in BACKENDS},
        zo_step_ms={be: round(zo_ms[be] * 1e3, 2) for be in BACKENDS},
        prefill_speedup_online=round(pf_ms["dense"] / pf_ms["online"], 3),
        prefill_speedup_pallas=round(pf_ms["dense"] / pf_ms["pallas"], 3),
        zo_step_speedup_online=round(zo_ms["dense"] / zo_ms["online"], 3),
        zo_step_speedup_pallas=round(zo_ms["dense"] / zo_ms["pallas"], 3),
        model_flops=flops, achieved_gflops=gflops, mfu=mfu,
        peak_flops=peak, platform=platform_key(),
        prefill_max_err=pf_err, zo_g_max_err=zo_err,
        no_ss_intermediate=no_ss, parity_ok=bool(parity_ok))
    print(f"  {cfg.name:24s} S={S:5d} "
          f"prefill d/o/p {row['prefill_ms']['dense']:.0f}/"
          f"{row['prefill_ms']['online']:.0f}/"
          f"{row['prefill_ms']['pallas']:.0f}ms  "
          f"zo d/o/p {row['zo_step_ms']['dense']:.0f}/"
          f"{row['zo_step_ms']['online']:.0f}/"
          f"{row['zo_step_ms']['pallas']:.0f}ms  "
          f"{'ok' if parity_ok else 'FAIL'}")
    return row


def run(smoke: bool = False, seed: int = 0, reps: int = 3,
        autotune: bool = False) -> dict:
    archs = [TINY] if smoke else [TINY, get_config("qwen3-4b").reduced()]
    lengths = (256,) if smoke else (256, 1024, 2048)
    rows = [bench_row(cfg, S, seed, reps, autotune=autotune)
            for cfg in archs for S in lengths]
    return {
        "table": "attn", "rows": rows,
        "backends": list(BACKENDS),
        "autotuned": bool(autotune),
        "all_parity_ok": all(r["parity_ok"] for r in rows),
        "all_no_ss": all(all(r["no_ss_intermediate"].values())
                         for r in rows),
        "basis": "prefill: models/decode.prefill right-padded with per-row "
                 "lengths at S_max=S; zo_step: one T=1 "
                 "fl_step.make_fl_train_step (2 forwards at S). CPU wall "
                 "times run the pallas rows in interpret mode and validate "
                 "structure + parity; the [S,S]-free jaxpr property is the "
                 "hardware-transferable claim (DESIGN.md §perf). mfu = "
                 "roofline model FLOPs / wall / HOST_PEAK_FLOPS[platform] "
                 "(launch/roofline.py): comparable across rows, nominal in "
                 "absolute terms while the platform is 'interpret'.",
        "all_ok": all(r["parity_ok"] for r in rows)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny arch, S=256 only (CI)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--autotune", action="store_true",
                    help="ensure kernels.autotune table entries for each "
                         "(arch, S) before timing (cached keys reused)")
    a = ap.parse_args()
    res = run(smoke=a.smoke, seed=a.seed, reps=a.reps, autotune=a.autotune)
    # smoke saves under its own name so CI / local smoke runs never
    # clobber the committed full-matrix artifact
    print("saved:", C.save_result(
        "BENCH_attn_smoke" if a.smoke else "BENCH_attn", res))


if __name__ == "__main__":
    main()
