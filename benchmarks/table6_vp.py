"""Paper Table 6 / Figure 4: MEERKAT-VP vs MEERKAT vs Random Client
Selection under Non-IID data, same communication frequency and sparsity.

MEERKAT-VP runs the VPCS calibration (Algorithm 1): the server reconstructs
GradIP trajectories from uploaded scalars, flags extreme Non-IID clients,
and early-stops them to T=1.  Random-CS early-stops the same *number* of
random clients (paper's control).

Client pool mixes Dirichlet clients with single-label extreme clients so
the heterogeneity signal that VPCS detects actually exists at tiny scale.
"""
from __future__ import annotations

import argparse

from benchmarks import common as C
from repro.configs.base import FLConfig
from repro.core import Client, FederatedZO
from repro.data.partition import dirichlet_partition, single_label_partition, subset


def _mixed_clients(prob, n_bal, n_skew, seed, batch_size=C.BATCH):
    labels = prob.train["label"]
    parts_b = dirichlet_partition(labels, n_bal, alpha=5.0, seed=seed)
    parts_s = single_label_partition(labels, n_skew, seed=seed + 1)
    clients = [Client(k, subset(prob.train, p), batch_size)
               for k, p in enumerate(parts_b + parts_s)]
    return clients, list(range(n_bal, n_bal + n_skew))


DENS = 5e-2  # GradIP needs local-convergence capacity (see fig3)


def _server(prob, clients, T, lr, seed):
    fl = FLConfig(n_clients=len(clients), local_steps=T, lr=lr, eps=C.ZO_EPS,
                  density=DENS, seed=seed, batch_size=C.BATCH,
                  vp_calibration_steps=200, vp_init_steps=40,
                  vp_later_steps=40, vp_sigma=0.25, vp_sigma_relative=True,
                  vp_rho_later=3.0, vp_rho_quie=0.6)
    space = C.make_space(prob, "meerkat", density=DENS, seed=seed)
    return FederatedZO(prob.loss, prob.params, space, fl, clients,
                       eval_fn=prob.evaluate)


def run(quick: bool = True, seed: int = 0, lr: float = 2e-2) -> dict:
    Ts = [10] if quick else [10, 30, 50]
    rounds = 30 if quick else 60
    prob = C.build_problem(seed=seed)
    rows = []
    detection = None
    for T in Ts:
        # -- meerkat-vp: calibrate -> flag -> early-stop --------------------
        clients, true_skew = _mixed_clients(prob, 6, 2, seed)
        srv_vp = _server(prob, clients, T, lr, seed)
        gp = C.gp_vector(prob, srv_vp.space)
        results, flagged, _ = srv_vp.calibrate_vp(gp)
        if detection is None:
            hits = len(set(flagged) & set(true_skew))
            detection = dict(flagged=flagged, true_skew=true_skew,
                             precision=hits / max(1, len(flagged)),
                             recall=hits / len(true_skew))
            print(f"  VPCS flagged {flagged} (true skew {true_skew})")
        srv_vp.run(rounds)
        m_vp = C.final_metrics(srv_vp, prob)

        # -- meerkat (no early stopping) -------------------------------------
        clients, _ = _mixed_clients(prob, 6, 2, seed)
        srv_mk = _server(prob, clients, T, lr, seed)
        srv_mk.run(rounds)
        m_mk = C.final_metrics(srv_mk, prob)

        # -- random client selection (same #early-stopped) -------------------
        clients, _ = _mixed_clients(prob, 6, 2, seed)
        srv_rd = _server(prob, clients, T, lr, seed)
        srv_rd.early_stop_random(max(1, len(flagged)), seed=seed + 7)
        srv_rd.run(rounds)
        m_rd = C.final_metrics(srv_rd, prob)

        for name, m in [("meerkat-vp", m_vp), ("meerkat", m_mk),
                        ("random-cs", m_rd)]:
            rows.append(dict(method=name, T=T, rounds=rounds,
                             acc=m["acc"], loss=m["loss"]))
            print(f"  T={T:3d} {name:11s} acc={m['acc']:.3f} "
                  f"loss={m['loss']:.3f}")
    accs = {(r["method"], r["T"]): r["acc"] for r in rows}
    ok = all(accs[("meerkat-vp", T)] >= accs[("meerkat", T)] - 0.02
             for T in Ts)
    return {"table": "table6_vp", "rows": rows, "vpcs_detection": detection,
            "claim_vp_ge_meerkat": bool(ok)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("table6_vp", res))


if __name__ == "__main__":
    main()
