"""Shared harness for the paper-table benchmarks.

Builds the reduced-scale (CPU-feasible) federated fine-tuning problem:
tiny decoder LM + synthetic classification-LM tasks + Dirichlet / IID /
single-label client partitions, and a FederatedZO server per method
(MEERKAT sensitivity mask / weight-magnitude / random / Full-FedZO dense /
LoRA-FedZO).  Every benchmark module calls into this and reports a dict
that `benchmarks/run.py` collects into runs/bench/*.json.

Scale note (DESIGN.md §7): the paper's GLUE tasks + 1-2B models are replaced
by a distribution-equivalent synthetic family + a 2-layer model; claims
checked here are *directional* (method orderings, dynamics), the full-size
configs are exercised structurally by the dry-run.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.configs.tiny import TINY, TINY_LORA
from repro.core import (Client, DenseSpace, FederatedZO, LoRASpace,
                        magnitude_mask, pretrain_gradient_vec, random_mask,
                        sensitivity_mask)
from repro.data.corpus import pretrain_batches
from repro.data.partition import (dirichlet_partition, iid_partition,
                                  single_label_partition, subset)
from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
from repro.models import Model

RUNS_DIR = os.path.join(os.path.dirname(__file__), "..", "runs", "bench")

# Benchmark-wide reduced-scale defaults.
SPEC = TaskSpec(vocab=512, n_classes=4, seq_len=16, topic_tokens=24)
N_TRAIN = 2048
N_EVAL = 512
DENSITY = 1e-2          # u for the tiny model (paper: 1e-3 at 1-2B params)
ZO_LR = 2e-3
ZO_EPS = 1e-3
BATCH = 16


@dataclass
class Problem:
    model: Model
    params: dict
    loss: callable          # mean classification loss
    per_example: callable
    evaluate: callable      # jitted -> {loss, acc}
    spec: TaskSpec
    train: Dict[str, np.ndarray]
    eval_batch: Dict[str, jnp.ndarray]
    pretrain: list          # C4-proxy batches (for masks + GradIP)

    def lm_loss(self, params, batch):
        return self.model.loss(params, batch)


def build_problem(seed: int = 0, lora: bool = False,
                  spec: TaskSpec = SPEC) -> Problem:
    cfg = TINY_LORA if lora else TINY
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    loss, per_example, evaluate = make_task_fns(model, spec)
    train = sample_dataset(spec, N_TRAIN, seed=seed + 1)
    ev = sample_dataset(spec, N_EVAL, seed=seed + 2)
    eval_batch = {k: jnp.asarray(v) for k, v in ev.items()}
    pre = [{k: jnp.asarray(v) for k, v in b.items()}
           for b in pretrain_batches(spec, n_batches=8, batch_size=32,
                                     seed=seed + 3)]
    return Problem(model, params, loss, per_example, evaluate, spec,
                   train, eval_batch, pre)


def make_space(problem: Problem, method: str, density: float = DENSITY,
               seed: int = 0):
    """method in {meerkat, magnitude, random, full, lora}."""
    p = problem.params
    if method == "meerkat":
        # sensitivity on *pre-training* LM loss (transferable mask, §2.1)
        return sensitivity_mask(problem.lm_loss, p, problem.pretrain, density)
    if method == "magnitude":
        return magnitude_mask(p, density)
    if method == "random":
        return random_mask(p, density, seed=seed, balanced=False)
    if method == "full":
        return DenseSpace(p)
    if method == "lora":
        return LoRASpace(p)
    raise ValueError(method)


def make_clients(problem: Problem, n_clients: int, partition: str,
                 alpha: float = 0.5, seed: int = 0,
                 batch_size: int = BATCH) -> List[Client]:
    labels = problem.train["label"]
    if partition == "iid":
        parts = iid_partition(len(labels), n_clients, seed=seed)
    elif partition == "dirichlet":
        parts = dirichlet_partition(labels, n_clients, alpha, seed=seed)
    elif partition == "single_label":
        parts = single_label_partition(labels, n_clients, seed=seed)
    else:
        raise ValueError(partition)
    return [Client(k, subset(problem.train, parts[k]), batch_size)
            for k in range(n_clients)]


def make_server(problem: Problem, method: str, *, partition: str = "dirichlet",
                alpha: float = 0.5, T: int = 1, n_clients: int = 8,
                density: float = DENSITY, lr: float = ZO_LR,
                eps: float = ZO_EPS, seed: int = 0,
                rounds: int = 0) -> FederatedZO:
    space = make_space(problem, method, density=density, seed=seed)
    fl = FLConfig(n_clients=n_clients, rounds=rounds, local_steps=T, lr=lr,
                  eps=eps, density=density, mask_kind=method, seed=seed,
                  batch_size=BATCH)
    clients = make_clients(problem, n_clients, partition, alpha=alpha,
                           seed=seed)
    return FederatedZO(problem.loss, problem.params, space, fl, clients,
                       eval_fn=problem.evaluate)


def final_metrics(server: FederatedZO, problem: Problem) -> Dict[str, float]:
    m = server.eval_fn(server.params, problem.eval_batch)
    return {k: float(v) for k, v in m.items()}


def gp_vector(problem: Problem, space) -> jnp.ndarray:
    """Server-held pre-training gradient restricted to the space (GradIP)."""
    return pretrain_gradient_vec(problem.lm_loss, problem.params, space,
                                 problem.pretrain)


def roofline_flops(cfg, *, step: str, B: int, S: int) -> float:
    """Analytic model FLOPs for one benchmark step via
    ``launch/roofline.py`` (active-param matmuls + the quadratic
    attention term, layer-pattern aware).

    Fails loudly — RuntimeError — when the roofline model cannot produce
    a positive finite FLOP count for this (arch, step), instead of letting
    a benchmark row silently emit null MFU."""
    import math

    from repro.launch import roofline as R
    try:
        f = R.step_model_flops(cfg, B, S, step)
    except Exception as e:
        raise RuntimeError(
            f"roofline model FLOPs unavailable for arch {cfg.name!r} "
            f"step {step!r} (B={B}, S={S}): {e}") from e
    if not math.isfinite(f) or f <= 0:
        raise RuntimeError(
            f"roofline model FLOPs for arch {cfg.name!r} step {step!r} "
            f"came out {f!r}; the FLOPs model does not cover this arch")
    return f


def mfu(flops: float, seconds: float, peak: float | None = None) -> float:
    """Achieved-FLOP/s fraction of the platform peak.  ``peak`` defaults
    to ``roofline.host_peak_flops()`` — which raises for platforms missing
    from ``HOST_PEAK_FLOPS`` rather than returning null."""
    from repro.launch import roofline as R
    if peak is None:
        peak = R.host_peak_flops()
    return flops / max(seconds, 1e-12) / peak


def save_result(name: str, result: dict) -> str:
    os.makedirs(RUNS_DIR, exist_ok=True)
    path = os.path.join(RUNS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=float)
    return os.path.abspath(path)


def timed(fn, *a, **kw):
    t0 = time.time()
    out = fn(*a, **kw)
    return out, time.time() - t0
