"""Beyond-paper ablation: K-direction ZO at a fixed forward-pass budget.

Each local step can average K independent perturbation directions
(core/zo.py n_dirs): K x forwards per step for ~1/K estimator variance,
upload = K scalars/step, virtual path still exact (tests/test_core_zo).
At a fixed total-forwards budget, is it better to take many noisy steps
(K=1, paper) or fewer averaged ones (K>1)?

Theory guess: with the stability-limited lr fixed, variance reduction
lets K>1 run a larger lr; at the same lr, K=1's extra steps usually win.
We report both at their per-K tuned lr.
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from benchmarks import common as C
from repro.core import make_local_run, round_keys


def run(quick: bool = True, seed: int = 0, density: float = 1e-2,
        budget: int = 600) -> dict:
    prob = C.build_problem(seed=seed)
    space = C.make_space(prob, "meerkat", density=density)
    client = C.make_clients(prob, 1, "iid", seed=seed, batch_size=32)[0]
    rows = []
    for K, lr in [(1, 5e-2), (2, 1e-1), (4, 2e-1)]:
        T = budget // K
        client.ptr = 0
        run_fn = make_local_run(prob.loss, space, eps=C.ZO_EPS, lr=lr,
                                n_dirs=K)
        keys = round_keys(seed, 0, T)
        batches = {k: jnp.asarray(v) for k, v in
                   client.next_batches(T).items()}
        import jax
        delta, gs = jax.jit(run_fn)(prob.params, keys, batches,
                                    jnp.zeros((space.n,), jnp.float32))
        m = prob.evaluate(space.add(prob.params, delta), prob.eval_batch)
        rows.append(dict(K=K, T=T, lr=lr, forwards=2 * K * T,
                         acc=float(m["acc"]), loss=float(m["loss"])))
        print(f"  K={K} T={T:4d} lr={lr:.0e} acc={float(m['acc']):.3f} "
              f"loss={float(m['loss']):.3f}")
    accs = {r["K"]: r["acc"] for r in rows}
    return {"table": "ablation_multi_dir", "rows": rows,
            "claim_all_configs_learn": bool(min(accs.values()) > 0.4)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("ablation_multi_dir", res))


if __name__ == "__main__":
    main()
