"""Theorem 2.1 validation: the steady-state error floor grows with T.

Federation of heterogeneous quadratics: client k minimizes
f_k(w) = 0.5 (w - t_k)^T H_k (w - t_k) with per-client diagonal curvature
H_k and spread targets t_k.  With T > 1 local steps per round, averaging
the clients' T-step maps has a fixed point that is *biased away* from the
global optimum w* = (sum H_k)^-1 sum H_k t_k — the classic Non-IID client
drift the paper's steady-state term O(T/(2+u)) captures; T = 1 removes
the bias (only the ZO variance floor remains).

Note a subtlety this benchmark is built around: with *homogeneous*
curvature (H_k = I) the averaged local maps have fixed point exactly w*
for every T — heterogeneous curvature is what makes local steps drift.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import DenseSpace, round_keys
from repro.core.fl_step import make_fl_round_step


def run(quick: bool = True, seed: int = 0, d: int = 32, K: int = 8,
        lr: float = 2e-2, spread: float = 3.0) -> dict:
    Ts = [1, 5, 20] if quick else [1, 2, 5, 10, 20, 50]
    total_steps = 4000 if quick else 12000
    tail_frac = 0.25

    k1, k2 = jax.random.split(jax.random.key(seed))
    targets = spread * jax.random.normal(k1, (K, d))          # client optima
    # log-uniform per-client diagonal curvature in [0.2, 2.0]
    H = jnp.exp(jax.random.uniform(k2, (K, d),
                                   minval=jnp.log(0.2), maxval=jnp.log(2.0)))
    w_star = (H * targets).sum(0) / H.sum(0)

    def global_loss(w):
        return float(0.5 * jnp.mean(jnp.sum(H * (w - targets) ** 2, -1)))

    f_star = global_loss(w_star)
    params = {"w": jnp.zeros((d,))}
    space = DenseSpace(params)

    def loss(p, b):  # b carries the client's (t_k, h_k) row
        return 0.5 * jnp.sum(b["h"] * (p["w"] - b["t"]) ** 2)

    rows = []
    for T in Ts:
        rounds = total_steps // T
        step = jax.jit(make_fl_round_step(loss, space, eps=1e-4, lr=lr, T=T))
        p = params
        tail = []
        for r in range(rounds):
            keys = round_keys(seed, r, T)
            batches = {"t": jnp.broadcast_to(targets[:, None, :], (K, T, d)),
                       "h": jnp.broadcast_to(H[:, None, :], (K, T, d))}
            p, _ = step(p, keys, batches)
            if r >= int(rounds * (1 - tail_frac)):
                tail.append(global_loss(p["w"] if isinstance(p, dict)
                                        else p) - f_star)
        floor = float(np.mean(tail))
        rows.append(dict(T=T, rounds=rounds, floor=floor))
        print(f"  T={T:3d} rounds={rounds:5d} steady-state excess loss "
              f"= {floor:.5f}")
    floors = [r["floor"] for r in rows]
    monotone = all(floors[i] <= floors[i + 1] * 1.1
                   for i in range(len(floors) - 1))
    return {"table": "error_floor", "rows": rows, "f_star": f_star,
            "claim_floor_grows_with_T": bool(monotone
                                             and floors[-1] > 1.5 * floors[0])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("error_floor", res))


if __name__ == "__main__":
    main()
