"""Serving benchmark: naive flush batching vs continuous batching.

Identical request streams (mixed prompt lengths, mixed generation budgets)
through both engines on the tiny CPU config and the qwen3-4b reduced()
variant (DESIGN.md §7 scale substitution).  Both engines are warmed with
one full wave first, so the timed wave measures steady-state serving —
which for the continuous engine must involve zero re-compiles (asserted
here and in tests/test_continuous_batching.py).

Metrics per engine: wall-clock tok/s over generated tokens and mean
time-to-first-token.  Naive TTFT is per *chunk*: a request's first token
exists only when its whole padded batch finishes its fixed-length decode
scan; continuous TTFT comes from the engine's per-request timestamps.

Writes runs/bench/BENCH_serve.json.  CPU wall times validate the *schedule*
(fewer wasted slot-steps, no retraces), not TPU performance.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks import common as C
from repro.configs import get_config
from repro.configs.tiny import TINY
from repro.models import Model
from repro.serving.engine import ContinuousBatchingEngine, ServeEngine


def _workload(cfg, n_requests: int, seed: int):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab,
                            size=int(rng.integers(4, 24))).astype(np.int32)
               for _ in range(n_requests)]
    # high-variance budgets: where early exit + slot refill pay off
    news = [int(rng.choice([2, 4, 8, 24])) for _ in range(n_requests)]
    return prompts, news


def _run_naive(model, params, prompts, news, max_batch: int, bucket: int):
    """Flush engine, chunk by chunk, timing each chunk's completion (the
    earliest moment any of its requests sees a token)."""
    eng = ServeEngine(model, params, max_batch=max_batch, bucket=bucket)
    t0 = time.perf_counter()
    ttfts, n_tok = [], 0
    for i in range(0, len(prompts), max_batch):
        for p, m in zip(prompts[i:i + max_batch], news[i:i + max_batch]):
            eng.submit(p, max_new_tokens=m)
        outs = eng.flush()
        t_done = time.perf_counter() - t0
        ttfts += [t_done] * len(outs)
        n_tok += sum(len(o) for o in outs)
    wall = time.perf_counter() - t0
    return dict(tok_s=round(n_tok / wall, 2), ttft_mean_s=round(
        float(np.mean(ttfts)), 4), wall_s=round(wall, 3), tokens=n_tok)


def _run_continuous(model, params, prompts, news, max_slots: int,
                    S_max: int, bucket: int, warm_misses=None):
    eng = ContinuousBatchingEngine(model, params, max_slots=max_slots,
                                   S_max=S_max, bucket=bucket)
    if warm_misses is not None:
        eng.compile_cache = warm_misses  # reuse the warmed cache
    t0 = time.perf_counter()
    for p, m in zip(prompts, news):
        eng.submit(p, max_new_tokens=m)
    outs = eng.run()
    wall = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    s = eng.stats
    return dict(tok_s=round(n_tok / wall, 2),
                ttft_mean_s=round(s["ttft_mean_s"], 4),
                wall_s=round(wall, 3), tokens=n_tok,
                decode_steps=s["decode_steps"],
                compile_misses=s["compile_misses"],
                compile_hits=s["compile_hits"]), eng


def bench_arch(name: str, n_requests: int, seed: int, reps: int = 3):
    cfg = TINY if name == "tiny" else get_config(name).reduced()
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    prompts, news = _workload(cfg, n_requests, seed)
    max_batch = 4
    bucket = 8
    S_max = 24 + 24 + 8  # longest prompt bucket + largest budget + slack

    # ---- warm both engines (compiles excluded from the timed waves) ----
    _run_naive(model, params, prompts, news, max_batch, bucket)
    warm, warm_eng = _run_continuous(model, params, prompts, news, max_batch,
                                     S_max, bucket)

    # ---- timed waves: interleaved best-of-reps (machine-noise robust,
    # same protocol as microbench._t_min_group) ----
    naive, cont, eng = None, None, None
    for _ in range(reps):
        n = _run_naive(model, params, prompts, news, max_batch, bucket)
        c, eng = _run_continuous(model, params, prompts, news, max_batch,
                                 S_max, bucket,
                                 warm_misses=warm_eng.compile_cache)
        naive = n if naive is None or n["wall_s"] < naive["wall_s"] else naive
        cont = c if cont is None or c["wall_s"] < cont["wall_s"] else cont
    steady_recompiles = eng.compile_cache.misses - warm["compile_misses"]
    row = dict(arch=cfg.name, n_params=model.n_params, n_requests=n_requests,
               naive=naive, continuous=cont,
               speedup=round(cont["tok_s"] / max(naive["tok_s"], 1e-9), 3),
               steady_state_recompiles=int(steady_recompiles),
               continuous_ge_naive=cont["tok_s"] >= naive["tok_s"])
    print(f"  {cfg.name:24s} naive {naive['tok_s']:7.1f} tok/s "
          f"(ttft {naive['ttft_mean_s']:.2f}s) | continuous "
          f"{cont['tok_s']:7.1f} tok/s (ttft {cont['ttft_mean_s']:.2f}s) "
          f"x{row['speedup']:.2f}, {cont['decode_steps']} steps, "
          f"{steady_recompiles} steady-state recompiles")
    return row


def run(quick: bool = True, seed: int = 0):
    rows = [bench_arch("tiny", n_requests=16 if quick else 32, seed=seed)]
    rows.append(bench_arch("qwen3-4b", n_requests=12 if quick else 24,
                           seed=seed))
    return {"table": "serve", "rows": rows,
            "continuous_ge_naive_tiny": rows[0]["continuous_ge_naive"],
            "no_steady_state_recompiles": all(
                r["steady_state_recompiles"] == 0 for r in rows),
            "all_ok": (rows[0]["continuous_ge_naive"] and all(
                r["steady_state_recompiles"] == 0 for r in rows))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("BENCH_serve", res))


if __name__ == "__main__":
    main()
