"""Paper Table 7: MEERKAT robustness across sparsity densities at T=1.

Claim: performance is strong across orders of magnitude of density.

Proportionality note: the paper sweeps 5e-1..5e-5 on 1.2-2.6B-param models,
so even its sparsest setting keeps ~60k coords.  On the ~1e5-param tiny
model the *relative* equivalent of that regime is ~5e-1..5e-3 (53k..534
coords); 5e-4 (53 coords) is far beyond the paper's regime and is reported
(in --full mode) as a beyond-paper extreme, excluded from the claim.
"""
from __future__ import annotations

import argparse

from benchmarks import common as C

# steadier steps for denser spaces (stability lr ~ 1/(n+2), see table1)
LR_FOR_DENSITY = {5e-1: 5e-3, 5e-2: 2e-2, 5e-3: 1e-1, 5e-4: 2e-1}
CLAIM_DENSITIES = {5e-1, 5e-2, 5e-3}


def run(quick: bool = True, seed: int = 0, alpha: float = 0.5) -> dict:
    rounds = 300 if quick else 800
    densities = [5e-1, 5e-2, 5e-3] if quick else [5e-1, 5e-2, 5e-3, 5e-4]
    prob = C.build_problem(seed=seed)
    rows = []
    for dens in densities:
        for partition in ["iid", "dirichlet"]:
            srv = C.make_server(prob, "meerkat", partition=partition,
                                alpha=alpha, T=1, density=dens,
                                lr=LR_FOR_DENSITY[dens], seed=seed)
            (_, dt) = C.timed(srv.run, rounds)
            m = C.final_metrics(srv, prob)
            rows.append(dict(density=dens, partition=partition,
                             n_coords=srv.space.n, acc=m["acc"],
                             loss=m["loss"], wall_s=round(dt, 1)))
            print(f"  u={dens:.0e} ({srv.space.n:6d} coords) {partition:10s} "
                  f"acc={m['acc']:.3f} ({dt:.0f}s)")
    in_claim = [r["acc"] for r in rows if r["density"] in CLAIM_DENSITIES]
    best, worst = max(in_claim), min(in_claim)
    return {"table": "table7_sparsity", "rows": rows,
            "claim_robust_across_density": bool(worst > 0.7 * best
                                                and worst > 0.5)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("table7_sparsity", res))


if __name__ == "__main__":
    main()
