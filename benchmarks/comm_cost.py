"""Paper §2.3 communication claim: MEERKAT's payloads vs Full-FedZO.

Two parts:
1. *Measured* — run a few rounds of each server on the tiny problem and
   read the CommLog (upload = T scalars for every ZO method; download =
   aggregated scalars + seed at high frequency, or the space's value
   vector at low frequency vs the dense model for Full-FedZO).
2. *Analytic at paper scale* — for every assigned architecture, bytes per
   round per client at u=1e-3: dense model refresh vs sparse refresh vs
   scalar-only high-frequency sync.  The >=1000x saving is structural.
"""
from __future__ import annotations

import argparse

from benchmarks import common as C
from repro.configs import ASSIGNED
from repro.models.init import param_count


def run(quick: bool = True, seed: int = 0, T: int = 10,
        density: float = 1e-3) -> dict:
    prob = C.build_problem(seed=seed)
    measured = {}
    for method in ["meerkat", "full"]:
        srv = C.make_server(prob, method, T=T, seed=seed)
        srv.run(3)
        per_round_client = {
            "up_bytes": srv.comm.up_bytes / (3 * len(srv.clients)),
            "down_bytes": srv.comm.down_bytes / (3 * len(srv.clients)),
        }
        measured[method] = per_round_client
        print(f"  measured {method:8s} up={per_round_client['up_bytes']:.0f}B "
              f"down={per_round_client['down_bytes']:.0f}B /round/client")
    ratio_measured = (measured["full"]["down_bytes"]
                      / max(1.0, measured["meerkat"]["down_bytes"]))

    analytic = []
    for name, cfg in sorted(ASSIGNED.items()):
        d = param_count(cfg)
        n = max(1, int(d * density))
        dense_b = 4 * d
        sparse_b = 4 * n
        scalars_b = 4 * T + 8
        analytic.append(dict(arch=name, n_params=d,
                             dense_refresh_bytes=dense_b,
                             sparse_refresh_bytes=sparse_b,
                             highfreq_scalar_bytes=scalars_b,
                             saving_sparse=dense_b / sparse_b,
                             saving_highfreq=dense_b / scalars_b))
        print(f"  {name:24s} d={d/1e9:8.2f}B dense={dense_b/1e9:8.2f}GB "
              f"sparse={sparse_b/1e6:7.1f}MB x{dense_b/sparse_b:,.0f} "
              f"scalars={scalars_b}B x{dense_b/scalars_b:.1e}")
    min_saving = min(a["saving_sparse"] for a in analytic)
    return {"table": "comm_cost", "measured": measured,
            "measured_down_ratio": ratio_measured, "analytic": analytic,
            "claim_1000x": bool(min_saving >= 990)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("comm_cost", res))


if __name__ == "__main__":
    main()
