"""Paper Figure 3 / 7-11 + Figure 8: the GradIP phenomenon.

Track GradIP (Definition 2.3), the local ZO gradient norm, and the cosine
between the local and pre-training gradients over 100 local steps for an
IID client and a single-label (extreme Non-IID) client.

Claims checked (RQ2 / Claim 2):
* GradIP magnitude of the extreme Non-IID client decays toward zero; the
  IID client's keeps oscillating (later-phase mean stays high).
* The cosine stays near-orthogonal for both (Fig. 8a) — the gradient-norm
  trajectory is the driver (Fig. 8b).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import gradip_trajectory, make_local_run, round_keys


def _client_trajectory(prob, space, client, T, lr, eps, seed):
    run = make_local_run(prob.loss, space, eps=eps, lr=lr)
    keys = round_keys(seed, 0, T)
    b = client.next_batches(T)
    batches = {k: jnp.asarray(v) for k, v in b.items()}
    _, gs = jax.jit(run)(prob.params, keys, batches,
                         jnp.zeros((space.n,), jnp.float32))
    return gs


def run(quick: bool = True, seed: int = 0, T: int = 200,
        lr: float = 5e-2, density: float = 5e-2) -> dict:
    """density 5e-2 mirrors the paper's Fig. 3 setting (5e-3 at 1B params):
    the masked subspace must hold enough capacity for a single-label client
    to *locally converge* within the trajectory — that convergence is the
    GradIP decay."""
    prob = C.build_problem(seed=seed)
    space = C.make_space(prob, "meerkat", density=density)
    gp = C.gp_vector(prob, space)
    clients_iid = C.make_clients(prob, 4, "iid", seed=seed, batch_size=32)
    clients_nid = C.make_clients(prob, 4, "single_label", seed=seed,
                                 batch_size=32)
    keys = round_keys(seed, 0, T)

    out = {}
    for tag, client in [("iid", clients_iid[0]), ("noniid", clients_nid[0])]:
        gs = _client_trajectory(prob, space, client, T, lr, C.ZO_EPS, seed)
        ips, norms, coss = gradip_trajectory(space, keys, gs, gp)
        ips, norms, coss = (np.abs(np.asarray(x)) for x in (ips, norms, coss))
        n0 = max(1, T // 5)
        out[tag] = dict(
            gradip=np.asarray(ips).tolist(),
            init_avg=float(ips[:n0].mean()),
            later_avg=float(ips[-n0:].mean()),
            norm_init=float(norms[:n0].mean()),
            norm_later=float(norms[-n0:].mean()),
            cos_mean=float(coss.mean()),
        )
        out[tag]["rho_later"] = out[tag]["init_avg"] / (
            out[tag]["later_avg"] + 1e-12)
        print(f"  {tag:7s} GradIP init={out[tag]['init_avg']:.3f} "
              f"later={out[tag]['later_avg']:.3f} "
              f"rho={out[tag]['rho_later']:.2f} |cos|={out[tag]['cos_mean']:.3f}")

    return {
        "table": "fig3_gradip", "T": T, "density": density,
        "iid": out["iid"], "noniid": out["noniid"],
        # Non-IID decays much harder than IID oscillates
        "claim_noniid_decays_faster": bool(
            out["noniid"]["rho_later"] > 2.0 * out["iid"]["rho_later"]),
        "claim_norms_mirror_gradip": bool(
            out["noniid"]["norm_later"] / (out["noniid"]["norm_init"] + 1e-12)
            < out["iid"]["norm_later"] / (out["iid"]["norm_init"] + 1e-12)),
        "claim_cosine_near_orthogonal": bool(
            max(out["iid"]["cos_mean"], out["noniid"]["cos_mean"]) < 0.2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("fig3_gradip", res))


if __name__ == "__main__":
    main()
