"""Fault-tolerance benchmark: convergence under client dropout, and the
cost of crash recovery (ISSUE 9 tentpole; writes
``runs/bench/BENCH_fault.json`` / ``BENCH_fault_smoke.json``).

Two tables:

* **degradation** — one training run per drop rate on the same problem
  (deterministic ``repro.fault.FaultPlan`` schedules): final acc/loss,
  cumulative FL protocol bytes (``CommLog`` counts only traffic that
  actually happened — offline clients cost nothing), and the mean
  fraction of the fleet that reported per round.  This is the
  FedAvg-over-survivors story: accuracy should degrade gracefully, not
  cliff, as the reporting fraction falls.
* **recovery** — measured overhead of the checkpoint protocol on the
  same server: snapshot wall time, restore wall time, checkpoint size,
  a round's wall time for scale, and the ``resume_bitexact`` gate (a
  restored fresh server runs the next round bit-identically to the
  donor — the invariant ``tools/kill_recover.py`` drills end-to-end
  across processes and mesh shapes).

Gates: ``claim_resume_bitexact`` (hard bit-equality) and
``claim_comm_tracks_reporting`` (upload bytes strictly fall as the drop
rate rises — dropped uploads must not be billed).

Usage:
  PYTHONPATH=src python -m benchmarks.fault_bench           # full grid
  PYTHONPATH=src python -m benchmarks.fault_bench --smoke   # CI subset
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax
import numpy as np

from benchmarks import common as C
from repro.fault import FaultPlan

FULL_DROP_RATES = (0.0, 0.1, 0.2, 0.4)
SMOKE_DROP_RATES = (0.0, 0.25)


def _flat(tree) -> np.ndarray:
    return np.concatenate([np.asarray(leaf).ravel()
                           for leaf in jax.tree.leaves(tree)])


def run_degradation(prob, *, method: str, rounds: int, drop_rates,
                    late_rate: float, n_clients: int, seed: int):
    rows = []
    for dr in drop_rates:
        srv = C.make_server(prob, method, T=1, n_clients=n_clients,
                            seed=seed, rounds=rounds)
        fp = FaultPlan(n_clients, rounds, drop_rate=dr, late_rate=late_rate,
                       seed=seed)
        reported = []
        t0 = time.time()
        for _ in range(rounds):
            srv.run_round(faults=fp.round_faults(srv.round))
            reported.append(srv.last_round_info["n_reporting"])
        dt = time.time() - t0
        m = C.final_metrics(srv, prob)
        rows.append(dict(
            drop_rate=dr, late_rate=late_rate, rounds=rounds,
            acc=m["acc"], loss=m["loss"],
            up_bytes=srv.comm.up_bytes, down_bytes=srv.comm.down_bytes,
            mean_reporting_frac=round(float(np.mean(reported)) / n_clients,
                                      4),
            pending_at_end=len(srv._pending), wall_s=round(dt, 1)))
        print(f"  drop={dr:.2f} acc={m['acc']:.3f} loss={m['loss']:.3f} "
              f"report_frac={rows[-1]['mean_reporting_frac']:.2f} "
              f"up={srv.comm.up_bytes}B ({dt:.0f}s)")
    return rows


def run_recovery(prob, *, method: str, warm_rounds: int, n_clients: int,
                 seed: int):
    """Measure save/restore wall time + size against a round's cost, and
    gate bit-exact resume: donor and restored-fresh server must produce
    identical params after one more round."""
    srv = C.make_server(prob, method, T=1, n_clients=n_clients, seed=seed)
    for _ in range(warm_rounds):
        srv.run_round()
    (_, round_s) = C.timed(srv.run_round)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        (_, save_s) = C.timed(srv.save_checkpoint, path)
        ckpt_bytes = os.path.getsize(path)
        twin = C.make_server(prob, method, T=1, n_clients=n_clients,
                             seed=seed)
        (_, restore_s) = C.timed(twin.load_checkpoint, path)
    srv.run_round()
    twin.run_round()
    bitexact = bool(np.array_equal(_flat(srv.params), _flat(twin.params))
                    and srv.comm.up_bytes == twin.comm.up_bytes
                    and [c.ptr for c in srv.clients]
                    == [c.ptr for c in twin.clients])
    row = dict(round_s=round(round_s, 4), save_s=round(save_s, 4),
               restore_s=round(restore_s, 4), ckpt_bytes=ckpt_bytes,
               overhead_frac=round(save_s / max(round_s, 1e-9), 4),
               resume_bitexact=bitexact)
    print(f"  recovery: save={save_s:.3f}s restore={restore_s:.3f}s "
          f"round={round_s:.3f}s ckpt={ckpt_bytes}B bitexact={bitexact}")
    return row


def run(quick: bool = True, seed: int = 0, method: str = "random",
        rounds: int | None = None, late_rate: float = 0.1,
        n_clients: int = 8) -> dict:
    rounds = rounds or (12 if quick else 150)
    drop_rates = SMOKE_DROP_RATES if quick else FULL_DROP_RATES
    prob = C.build_problem(seed=seed)
    deg = run_degradation(prob, method=method, rounds=rounds,
                          drop_rates=drop_rates, late_rate=late_rate,
                          n_clients=n_clients, seed=seed)
    rec = run_recovery(prob, method=method, warm_rounds=2,
                       n_clients=n_clients, seed=seed)
    up = [r["up_bytes"] for r in deg]
    return {
        "table": "fault_tolerance", "rows": deg, "recovery": rec,
        "claim_resume_bitexact": rec["resume_bitexact"],
        "claim_comm_tracks_reporting": bool(
            all(a > b for a, b in zip(up, up[1:]))),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI subset; writes BENCH_fault_smoke.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default="random",
                    choices=["meerkat", "magnitude", "random", "full",
                             "lora"],
                    help="coordinate space (fault handling is "
                         "method-agnostic; random builds fastest)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the per-cell round budget")
    ap.add_argument("--late-rate", type=float, default=0.1,
                    help="straggler probability alongside each drop rate")
    a = ap.parse_args()
    res = run(quick=a.smoke, seed=a.seed, method=a.method, rounds=a.rounds,
              late_rate=a.late_rate)
    name = "BENCH_fault_smoke" if a.smoke else "BENCH_fault"
    print("saved:", C.save_result(name, res))


if __name__ == "__main__":
    main()
