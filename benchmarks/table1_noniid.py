"""Paper Table 1: MEERKAT vs Full-FedZO / Weight-Magnitude / LoRA-FedZO
under Non-IID (Dirichlet alpha=0.5) at the same synchronization frequency
(fixed local steps T), fixed total local-step budget.

Claim checked (RQ1 / Claim 1): MEERKAT outperforms full-parameter ZO and
the other sparsity baselines at every T.

Learning rates are per-method (the paper tunes within [2e-4, 2e-8] at 1-3B
scale; our tiny model needs larger steps).  Dense ZO *requires* a much
smaller lr for stability — lr_max ~ 1/(L(n+2)) with n = #perturbed coords —
which is precisely the paper's sparsity argument.
"""
from __future__ import annotations

import argparse

from benchmarks import common as C

# per-method tuned lr (grid over {2e-3..2e-1}; dense ZO diverges above ~2e-3
# at d~1e5 — the stability radius shrinks with perturbed-coordinate count,
# which is the paper's core sparsity argument)
METHOD_LR = {"meerkat": 1e-1, "magnitude": 5e-2, "lora": 2e-2, "full": 2e-3}


def run(quick: bool = True, seed: int = 0, partition: str = "dirichlet",
        alpha: float = 0.5, budget: int = 400) -> dict:
    Ts = [10, 30] if quick else [10, 30, 50, 100]
    methods = ["full", "magnitude", "lora", "meerkat"]
    prob = C.build_problem(seed=seed)
    prob_lora = C.build_problem(seed=seed, lora=True)
    rows = []
    for T in Ts:
        rounds = max(1, budget // T)
        for method in methods:
            p = prob_lora if method == "lora" else prob
            srv = C.make_server(p, method, partition=partition, alpha=alpha,
                                T=T, lr=METHOD_LR[method], seed=seed)
            (_, dt) = C.timed(srv.run, rounds)
            m = C.final_metrics(srv, p)
            rows.append(dict(method=method, T=T, rounds=rounds,
                             acc=m["acc"], loss=m["loss"], wall_s=round(dt, 1)))
            print(f"  T={T:3d} {method:10s} acc={m['acc']:.3f} "
                  f"loss={m['loss']:.3f} ({dt:.0f}s)")
    # claim: meerkat best (or tied-best) acc at each T
    ok = True
    for T in Ts:
        accs = {r["method"]: r["acc"] for r in rows if r["T"] == T}
        ok &= accs["meerkat"] >= max(v for k, v in accs.items()
                                     if k != "meerkat") - 0.02
    return {"table": "table1_noniid", "partition": partition, "alpha": alpha,
            "rows": rows, "claim_meerkat_best_per_T": bool(ok)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("table1_noniid", res))


if __name__ == "__main__":
    main()
