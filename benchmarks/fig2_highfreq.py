"""Paper Figure 2 / Table 8: high-frequency synchronization (T=1).

Claims checked:
* MEERKAT beats Full-FedZO and LoRA-FedZO at T=1 under both IID and Non-IID.
* At T=1 MEERKAT closes the IID <-> Non-IID gap (the paper's remarkable
  finding: near-equal average accuracy across the two distributions).
"""
from __future__ import annotations

import argparse

from benchmarks import common as C
from benchmarks.table1_noniid import METHOD_LR


def run(quick: bool = True, seed: int = 0, alpha: float = 0.5) -> dict:
    rounds = 300 if quick else 800
    prob = C.build_problem(seed=seed)
    prob_lora = C.build_problem(seed=seed, lora=True)
    rows = []
    for method in ["full", "lora", "meerkat"]:
        p = prob_lora if method == "lora" else prob
        for partition in ["iid", "dirichlet"]:
            srv = C.make_server(p, method, partition=partition, alpha=alpha,
                                T=1, lr=METHOD_LR[method], seed=seed)
            (_, dt) = C.timed(srv.run, rounds)
            m = C.final_metrics(srv, p)
            rows.append(dict(method=method, partition=partition,
                             rounds=rounds, acc=m["acc"], loss=m["loss"],
                             wall_s=round(dt, 1)))
            print(f"  {method:8s} {partition:10s} acc={m['acc']:.3f} "
                  f"({dt:.0f}s)")
    acc = {(r["method"], r["partition"]): r["acc"] for r in rows}
    gap = {m: acc[(m, "iid")] - acc[(m, "dirichlet")]
           for m in ["full", "lora", "meerkat"]}
    best_noniid = max(["full", "lora", "meerkat"],
                      key=lambda m: acc[(m, "dirichlet")])
    return {"table": "fig2_highfreq", "alpha": alpha, "rows": rows,
            "iid_noniid_gap": gap,
            "claim_meerkat_best_noniid": best_noniid == "meerkat",
            "claim_meerkat_small_gap": abs(gap["meerkat"]) <= 0.05}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--alpha", type=float, default=0.5)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed, alpha=a.alpha)
    print("saved:", C.save_result("fig2_highfreq", res))


if __name__ == "__main__":
    main()
