"""Kernel microbench: interpret-mode correctness + wall timings for every
Pallas kernel over a shape sweep, against the ref.py jnp oracles.

Timings on CPU interpret mode are NOT TPU performance — they validate the
kernel bodies; the roofline analysis (launch/roofline.py) covers perf.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.kernels import ref
from repro.kernels.ops import (flash_decode, gradip_flat, zo_dual_perturb_flat,
                               zo_fused_update_flat)


def _t(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def run(quick: bool = True, seed: int = 0) -> dict:
    key = jax.random.key(seed)
    rows = []

    sizes = [1024, 65_536] if quick else [1024, 65_536, 1_048_576]
    for n in sizes:
        k1, k2, k3, key = jax.random.split(key, 4)
        w = jax.random.normal(k1, (n,), jnp.float32)
        z = jax.random.normal(k2, (n,), jnp.float32)
        m = (jax.random.uniform(k3, (n,)) < 0.5).astype(jnp.float32)
        eps = 1e-3

        p, mi = zo_dual_perturb_flat(w, z, m, eps)
        rp, rm = ref.dual_perturb_ref(w, z, m, eps)
        err = float(jnp.max(jnp.abs(p - rp)) + jnp.max(jnp.abs(mi - rm)))
        dt = _t(zo_dual_perturb_flat, w, z, m, eps)
        rows.append(dict(kernel="zo_dual_perturb", n=n, max_err=err,
                         ms=dt * 1e3, ok=err < 1e-5))

        u = zo_fused_update_flat(w, z, m, 0.37)
        err = float(jnp.max(jnp.abs(u - ref.fused_update_ref(w, z, m, 0.37))))
        dt = _t(zo_fused_update_flat, w, z, m, 0.37)
        rows.append(dict(kernel="zo_fused_update", n=n, max_err=err,
                         ms=dt * 1e3, ok=err < 1e-5))

        g = gradip_flat(w, z, 1.7)
        rg = ref.gradip_reduce_ref(w, z, 1.7)
        err = float(jnp.abs(g - rg) / (jnp.abs(rg) + 1e-9))
        dt = _t(gradip_flat, w, z, 1.7)
        rows.append(dict(kernel="gradip_reduce", n=n, max_err=err,
                         ms=dt * 1e3, ok=err < 1e-4))

    shapes = ([(2, 2, 4, 64, 1024)] if quick
              else [(2, 2, 4, 64, 1024), (4, 8, 4, 128, 4096)])
    for (B, KVH, G, dh, S) in shapes:
        k1, k2, k3, key = jax.random.split(key, 4)
        q = jax.random.normal(k1, (B, KVH, G, dh), jnp.float32)
        kk = jax.random.normal(k2, (B, S, KVH, dh), jnp.float32)
        vv = jax.random.normal(k3, (B, S, KVH, dh), jnp.float32)
        length = S * 3 // 4
        o = flash_decode(q, kk, vv, length)
        r = ref.decode_attention_ref(q, kk, vv, length)
        err = float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                    - r.astype(jnp.float32))))
        dt = _t(flash_decode, q, kk, vv, length)
        rows.append(dict(kernel="flash_decode", n=f"B{B}S{S}", max_err=err,
                         ms=dt * 1e3, ok=err < 2e-2))

    for r in rows:
        print(f"  {r['kernel']:16s} n={r['n']!s:10s} err={r['max_err']:.2e} "
              f"{r['ms']:8.1f}ms {'ok' if r['ok'] else 'FAIL'}")
    return {"table": "microbench", "rows": rows,
            "all_ok": all(r["ok"] for r in rows)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("microbench", res))


if __name__ == "__main__":
    main()
