"""Kernel microbench: interpret-mode correctness + wall timings for every
Pallas kernel over a shape sweep, against the ref.py jnp oracles — plus the
end-to-end ZO *step* benchmark (naive pytree route vs fused flat kernel
route through the dispatch layer), written to runs/bench/BENCH_zo_step.json.

Timings on CPU interpret mode are NOT TPU performance — they validate the
kernel bodies; the roofline analysis (launch/roofline.py) covers perf.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.kernels import ref
from repro.kernels.ops import (flash_decode, gradip_flat, zo_dual_perturb_flat,
                               zo_fused_update_flat)


def _t(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps


def _t_min_group(fns: dict, *args, reps=3) -> dict:
    """Best-of-reps wall time per function, measured *interleaved* so CPU
    frequency/cache/contention drift hits every candidate equally — the
    robust protocol for comparative ms-scale timings on a shared machine.
    Returns {name: seconds}."""
    for fn in fns.values():
        fn(*args)  # compile
    best = {name: float("inf") for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.time()
            jax.block_until_ready(fn(*args))
            best[name] = min(best[name], time.time() - t0)
    return best


# ------------------------------------------------- end-to-end ZO step -------

def _step_problem(which: str, seed: int):
    """A real (model, per-example loss, masked space, batch) at one of the
    DESIGN.md §7 scale-substituted shapes: the tiny CPU config, or the
    qwen3-4b architecture via its reduced() variant."""
    from repro.configs import get_config
    from repro.configs.tiny import TINY
    from repro.core import random_mask
    from repro.data.synthetic import TaskSpec, make_task_fns, sample_dataset
    from repro.models import Model

    cfg = TINY if which == "tiny" else get_config("qwen3-4b").reduced()
    spec = TaskSpec(vocab=min(cfg.vocab, 512))
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    _, per_example, _ = make_task_fns(model, spec)
    space = random_mask(params, density=1e-2, seed=seed, balanced=False)
    data = sample_dataset(spec, 32, seed=seed + 1)
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    return params, per_example, space, batch, n_params


def _phase_bench(space, params, reps: int) -> dict:
    """Isolated perturb+update phase (no model forward), three routes over
    the same step semantics (see DESIGN.md §6, BENCH_zo_step):

    * fused    — zo_dual_perturb_flat + zo_fused_update_flat (7 HBM passes)
    * unfused  — the same flat math as separate jnp ops (13 passes); the
      hardware-transferable fusion comparison: fewer passes wins on any
      backend, CPU interpret included
    * scatter  — the pytree ``space.add`` chain.  On CPU its random-access
      sparse scatters are cheap, so it wins here; on TPU arbitrary-index
      scatter serializes (and erases GSPMD shardings — DESIGN.md §perf),
      which is what motivates the flat route
    """
    from repro.core import get_backing

    backing = get_backing(space, params)
    eps, lr, g = 1e-3, 1e-2, 0.5

    @jax.jit
    def fused(params, key):
        w = backing.flatten(params)
        z = backing.expand(space.sample_z(key))
        wp, wm = zo_dual_perturb_flat(w, z, None, eps)
        return wp, wm, zo_fused_update_flat(w, z, None, -lr * g)

    @jax.jit
    def unfused(params, key):
        w = backing.flatten(params)
        z = backing.expand(space.sample_z(key))
        m = jnp.asarray(backing.mask)
        pert = (eps * z * m).astype(w.dtype)
        return w + pert, w - pert, w + (-lr * g * z * m).astype(w.dtype)

    @jax.jit
    def scatter(params, key):
        z = space.sample_z(key)
        wp = space.add(params, eps * z)
        wm = space.add(wp, -2.0 * eps * z)
        return wp, wm, space.add(wm, (eps - lr * g) * z)

    key = jax.random.key(0)
    f, u = fused(params, key), unfused(params, key)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(f, u))
    reps = max(8 * reps, 40)  # phase calls are ms-scale; de-noise hard
    ts = _t_min_group(dict(fused=fused, unfused=unfused, scatter=scatter),
                      params, key, reps=reps)
    return dict(
        fused_ms=round(ts["fused"] * 1e3, 3),
        unfused_ms=round(ts["unfused"] * 1e3, 3),
        scatter_ms=round(ts["scatter"] * 1e3, 3),
        max_err=err, parity_ok=err < 1e-5)


def run_zo_step(quick: bool = True, seed: int = 0) -> dict:
    """End-to-end ZO train-step benchmark, naive vs fused, per DESIGN.md §6.

    Per arch (tiny and the scale-substituted qwen3_4b-reduced, §7):

    * ``step``  — the T=1 high-frequency MEERKAT step (Alg. 3, the
      production hot path) measured inside the jitted ``n_steps``-scan of
      ``fl_step.make_fl_train_loop`` (the compiled training burst) on
      backend="ref" (naive pytree route) vs backend="pallas" (fused flat
      route), with output parity over the whole burst.  The scan is the
      realistic hot loop — and on the fused route it hoists the per-step
      ``backing.flatten(params)`` / tile re-padding round-trip out of the
      step (once per burst), which repeated single-step calls paid per
      step and which inverted the fused-vs-naive comparison on qwen3_4b
      (ISSUE 4 satellite).
    * ``phase`` — the perturb/update phase alone (see ``_phase_bench``):
      ``fused_ge_naive`` asserts the fused kernels beat the *unfused flat
      chain* they replace, the comparison that transfers across backends.
      End-to-end CPU numbers also include interpret-mode overhead and a
      scatter route whose CPU/TPU cost relation is inverted, so they are
      reported but not gated on this container (see the module docstring).
    """
    from repro.core.fl_step import make_fl_train_loop

    reps = 5 if quick else 20
    e2e_reps = max(6 * reps, 30)  # loop timings gate the bench; de-noise
    n_steps = 8
    rows = []
    for which in ("tiny", "qwen3_4b"):
        params, per_example, space, batch, n_params = _step_problem(which,
                                                                    seed)
        # burst batches: the same batch at every scanned step (bench-only;
        # data content does not affect route cost)
        batches = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_steps,) + x.shape), batch)
        args = (params, jax.random.key(seed + 2), batches)

        def build(be):
            return jax.jit(make_fl_train_loop(
                per_example, space, eps=1e-3, lr=1e-2, n_clients=4,
                n_steps=n_steps, backend=be))

        parity_loops = {"naive": build("ref"), "fused": build("pallas")}
        outs = {be: fn(*args) for be, fn in
                zip(("ref", "pallas"), parity_loops.values())}
        g_err = float(jnp.max(jnp.abs(outs["ref"][1] - outs["pallas"][1])))
        w_err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                    zip(jax.tree.leaves(outs["ref"][0]),
                        jax.tree.leaves(outs["pallas"][0])))
        # best-of over FRESH jit instances per route, interleaved reps
        # within each: on this container an individual executable's buffer
        # placement can land pathologically (a stable ~2x penalty for that
        # instance), so a single-instance comparison measures allocator
        # luck, not the route.  Per-route minimum across instances recovers
        # each route's healthy cost.
        n_inst = 3
        naive_ts, fused_ts = [], []
        for i in range(n_inst):
            # the parity pair doubles as timing instance 0 (it is a fresh
            # jit instance of each route; re-building it would only pay
            # two more burst compiles)
            loops = parity_loops if i == 0 else {"naive": build("ref"),
                                                 "fused": build("pallas")}
            ts = _t_min_group(loops, *args,
                              reps=max(2, e2e_reps // n_inst))
            naive_ts.append(ts["naive"])
            fused_ts.append(ts["fused"])
        speedup = min(naive_ts) / min(fused_ts)
        naive_ms = min(naive_ts) * 1e3 / n_steps
        fused_ms = min(fused_ts) * 1e3 / n_steps
        phase = _phase_bench(space, params, reps)
        rows.append(dict(
            arch=which, n_params=n_params, n_coords=space.n,
            step_naive_ms=round(naive_ms, 3),
            step_fused_ms=round(fused_ms, 3),
            step_naive_per_s=round(1e3 / naive_ms, 2),
            step_fused_per_s=round(1e3 / fused_ms, 2),
            step_speedup=round(speedup, 3),
            phase=phase,
            phase_speedup=round(phase["unfused_ms"] / phase["fused_ms"], 3),
            g_max_err=g_err, w_max_err=w_err,
            parity_ok=g_err < 5e-2 and w_err < 1e-3 and phase["parity_ok"]))
        r = rows[-1]
        print(f"  zo_step {which:10s} n={n_params:>9d} coords={space.n:>7d} "
              f"e2e x{r['step_speedup']:.2f} "
              f"phase fused={phase['fused_ms']:.1f}ms "
              f"unfused={phase['unfused_ms']:.1f}ms "
              f"scatter={phase['scatter_ms']:.1f}ms "
              f"x{r['phase_speedup']:.2f} "
              f"{'ok' if r['parity_ok'] else 'FAIL'}")
    # gate on rows whose phase does measurable work: below ~2 ms the
    # 7-vs-13-pass difference is microseconds — under the wall-clock timer's
    # resolution on CPU — so sub-ms rows are reported but not gated (and if
    # every row is sub-resolution the criterion is vacuously met rather
    # than decided by noise)
    gated = [r for r in rows if r["phase"]["fused_ms"] >= 2.0]
    # strict: fused kernels literally >= the unfused chain on this run.
    # within_noise (>= 0.85 off-TPU): XLA auto-fuses the unfused jnp chain
    # on CPU, so the two routes stream comparable bytes and wall-clock
    # ratios swing ~10-15% on a shared box; the kernels' structural win
    # (single-read dual output, no mask stream, no scatter) is a TPU
    # property — see DESIGN.md \u00a76/\u00a7perf.  Both are null when no
    # row has >= 2 ms of phase work to measure (never decided by noise).
    floor = 0.85 if jax.default_backend() != "tpu" else 1.0
    strict = (all(r["phase_speedup"] >= 1.0 for r in gated)
              if gated else None)
    within = (all(r["phase_speedup"] >= floor for r in gated)
              if gated else None)
    print(f"  zo_step fused_ge_naive={strict} within_noise={within} "
          f"(gated rows: {[r['arch'] for r in gated]})")
    return {
        "table": "zo_step", "rows": rows,
        "fused_ge_naive": strict,
        "fused_ge_naive_within_noise": within,
        "fused_ge_naive_basis":
            "phase: fused kernels vs the unfused flat chain they replace, "
            "over rows with >= 2 ms of phase work (null if none qualify). "
            "fused_ge_naive is the strict >= 1.0 comparison on this run; "
            "fused_ge_naive_within_noise tolerates 15% CPU timing noise, "
            "since XLA auto-fuses the unfused chain on CPU and the "
            "structural fusion win (single-read dual output, no mask "
            "stream) is realized on TPU. rows[].step_speedup is the "
            "end-to-end naive-pytree-vs-fused *per-step* comparison "
            "inside the jitted make_fl_train_loop burst (the "
            "realistic hot loop, where the fused route builds the "
            "flat vector once per burst instead of once per step, "
            "hoisting the per-step flatten that inverted this "
            "comparison on qwen3_4b, and auto-picks the forward "
            "strategy by model size: stacked-vmap (w+, w-) forward "
            "below STACK_FORWARDS_MAX_PARAMS, two sequential "
            "forwards above): per-route best over fresh jit "
            "instances x interleaved reps, robust to per-executable "
            "buffer-placement pathology on shared containers; CPU "
            "interpret-mode caveats per DESIGN.md \u00a76/\u00a7perf.",
        "all_ok": all(r["parity_ok"] for r in rows)}


def run(quick: bool = True, seed: int = 0) -> dict:
    key = jax.random.key(seed)
    rows = []

    sizes = [1024, 65_536] if quick else [1024, 65_536, 1_048_576]
    for n in sizes:
        k1, k2, k3, key = jax.random.split(key, 4)
        w = jax.random.normal(k1, (n,), jnp.float32)
        z = jax.random.normal(k2, (n,), jnp.float32)
        m = (jax.random.uniform(k3, (n,)) < 0.5).astype(jnp.float32)
        eps = 1e-3

        p, mi = zo_dual_perturb_flat(w, z, m, eps)
        rp, rm = ref.dual_perturb_ref(w, z, m, eps)
        err = float(jnp.max(jnp.abs(p - rp)) + jnp.max(jnp.abs(mi - rm)))
        dt = _t(zo_dual_perturb_flat, w, z, m, eps)
        rows.append(dict(kernel="zo_dual_perturb", n=n, max_err=err,
                         ms=dt * 1e3, ok=err < 1e-5))

        u = zo_fused_update_flat(w, z, m, 0.37)
        err = float(jnp.max(jnp.abs(u - ref.fused_update_ref(w, z, m, 0.37))))
        dt = _t(zo_fused_update_flat, w, z, m, 0.37)
        rows.append(dict(kernel="zo_fused_update", n=n, max_err=err,
                         ms=dt * 1e3, ok=err < 1e-5))

        g = gradip_flat(w, z, 1.7)
        rg = ref.gradip_reduce_ref(w, z, 1.7)
        err = float(jnp.abs(g - rg) / (jnp.abs(rg) + 1e-9))
        dt = _t(gradip_flat, w, z, 1.7)
        rows.append(dict(kernel="gradip_reduce", n=n, max_err=err,
                         ms=dt * 1e3, ok=err < 1e-4))

    shapes = ([(2, 2, 4, 64, 1024)] if quick
              else [(2, 2, 4, 64, 1024), (4, 8, 4, 128, 4096)])
    for (B, KVH, G, dh, S) in shapes:
        k1, k2, k3, key = jax.random.split(key, 4)
        q = jax.random.normal(k1, (B, KVH, G, dh), jnp.float32)
        kk = jax.random.normal(k2, (B, S, KVH, dh), jnp.float32)
        vv = jax.random.normal(k3, (B, S, KVH, dh), jnp.float32)
        length = S * 3 // 4
        o = flash_decode(q, kk, vv, length)
        r = ref.decode_attention_ref(q, kk, vv, length)
        err = float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                    - r.astype(jnp.float32))))
        dt = _t(flash_decode, q, kk, vv, length)
        rows.append(dict(kernel="flash_decode", n=f"B{B}S{S}", max_err=err,
                         ms=dt * 1e3, ok=err < 2e-2))

    for r in rows:
        print(f"  {r['kernel']:16s} n={r['n']!s:10s} err={r['max_err']:.2e} "
              f"{r['ms']:8.1f}ms {'ok' if r['ok'] else 'FAIL'}")

    step_res = run_zo_step(quick=quick, seed=seed)
    print("saved:", C.save_result("BENCH_zo_step", step_res))
    return {"table": "microbench", "rows": rows, "zo_step": step_res,
            "all_ok": all(r["ok"] for r in rows) and step_res["all_ok"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("microbench", res))


if __name__ == "__main__":
    main()
