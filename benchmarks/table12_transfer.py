"""Paper Tables 12/13 (+ §3.1 discussion): mask *transferability*.

MEERKAT selects its mask from pre-training-data gradients and claims the
selection transfers to downstream tasks.  We compare, at T=1 and the same
density:

* pretrain-mask (MEERKAT) — sensitivity on the C4-proxy LM loss;
* task-mask              — sensitivity on the downstream task loss
                           (privacy-leaking upper reference);
* random-mask            — lower control.

Claim (paper): pretrain-mask ~ task-mask >> random at equal density, so
the privacy-preserving pre-training mask costs ~nothing.
"""
from __future__ import annotations

import argparse

from benchmarks import common as C
from repro.core import sensitivity_mask


def run(quick: bool = True, seed: int = 0, density: float = 5e-3,
        lr: float = 1e-1) -> dict:
    rounds = 300 if quick else 800
    prob = C.build_problem(seed=seed)

    # task-mask: sensitivity of the downstream-task loss on pooled task data
    # (the paper's Task-Mask, Tables 12/13).  On the tiny model this mask
    # concentrates in the classification-head subspace and *underperforms*
    # the broad pretrain mask — a stronger version of the paper's own
    # conclusion that the privacy-preserving pretrain mask loses nothing.
    import jax.numpy as jnp
    task_batches = [{k: jnp.asarray(v[i * 64:(i + 1) * 64])
                     for k, v in prob.train.items()} for i in range(4)]
    spaces = {
        "pretrain-mask": C.make_space(prob, "meerkat", density=density),
        "task-mask": sensitivity_mask(prob.loss, prob.params, task_batches,
                                      density),
        "random-mask": C.make_space(prob, "random", density=density,
                                    seed=seed),
    }
    rows = []
    for name, space in spaces.items():
        from repro.configs.base import FLConfig
        from repro.core import FederatedZO
        fl = FLConfig(n_clients=8, local_steps=1, lr=lr, eps=C.ZO_EPS,
                      density=density, seed=seed, batch_size=C.BATCH)
        clients = C.make_clients(prob, 8, "dirichlet", alpha=0.5, seed=seed)
        srv = FederatedZO(prob.loss, prob.params, space, fl, clients,
                          eval_fn=prob.evaluate)
        (_, dt) = C.timed(srv.run, rounds)
        m = C.final_metrics(srv, prob)
        # mask overlap with the task mask (transferability metric)
        rows.append(dict(mask=name, n_coords=space.n, acc=m["acc"],
                         loss=m["loss"], wall_s=round(dt, 1)))
        print(f"  {name:14s} acc={m['acc']:.3f} loss={m['loss']:.3f} "
              f"({dt:.0f}s)")
    acc = {r["mask"]: r["acc"] for r in rows}
    return {"table": "table12_transfer", "density": density, "rows": rows,
            # transferability: the pretrain mask matches or beats the
            # privacy-leaking task mask (paper §3.1, Tables 12/13)
            "claim_pretrain_ge_task": bool(
                acc["pretrain-mask"] >= acc["task-mask"] - 0.05),
            "claim_pretrain_beats_random": bool(
                acc["pretrain-mask"] > acc["random-mask"] + 0.03)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("table12_transfer", res))


if __name__ == "__main__":
    main()
