"""Beyond-paper ablation: FedAvgM-style server momentum on the aggregated
sparse MEERKAT update.

The server's virtual-path reconstruction yields the exact averaged sparse
delta each round; applying momentum to it costs nothing in communication
(the state lives on the server's n sparse coordinates).  Hypothesis: at
T=1 the per-round updates are tiny and strongly correlated, so momentum
accelerates convergence under the same round budget.
"""
from __future__ import annotations

import argparse

from benchmarks import common as C
from repro.configs.base import FLConfig
from repro.core import FederatedZO


def run(quick: bool = True, seed: int = 0, lr: float = 5e-2,
        density: float = 1e-2) -> dict:
    rounds = 150 if quick else 500
    prob = C.build_problem(seed=seed)
    space = C.make_space(prob, "meerkat", density=density)
    rows = []
    for beta in [0.0, 0.5, 0.9]:
        fl = FLConfig(n_clients=8, local_steps=1, lr=lr, eps=C.ZO_EPS,
                      density=density, seed=seed, batch_size=C.BATCH,
                      server_momentum=beta)
        clients = C.make_clients(prob, 8, "dirichlet", alpha=0.5, seed=seed)
        srv = FederatedZO(prob.loss, prob.params, space, fl, clients,
                          eval_fn=prob.evaluate)
        (_, dt) = C.timed(srv.run, rounds)
        m = C.final_metrics(srv, prob)
        rows.append(dict(beta=beta, acc=m["acc"], loss=m["loss"],
                         wall_s=round(dt, 1)))
        print(f"  beta={beta:.1f} acc={m['acc']:.3f} loss={m['loss']:.3f} "
              f"({dt:.0f}s)")
    acc = {r["beta"]: r["acc"] for r in rows}
    best_beta = max(acc, key=acc.get)
    return {"table": "ablation_server_momentum", "rows": rows,
            "best_beta": best_beta,
            "claim_momentum_helps": bool(max(acc[0.5], acc[0.9])
                                         >= acc[0.0])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("ablation_server_momentum", res))


if __name__ == "__main__":
    main()
