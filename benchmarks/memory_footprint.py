"""Paper §1/§2.3 memory claim: ZO fine-tuning needs no activation storage.

Compares `compiled.memory_analysis()` of the production MEERKAT `zo_fl`
step against the first-order (backprop) step for the same architecture,
input shape and mesh — the dry-run machinery gives exact per-device
numbers.  The backward pass must keep every layer's activations live
(or pay remat recompute); the ZO dual forward keeps one layer period.

The measurement runs in a subprocess because it needs the 512 forced host
devices before jax initializes (benchmarks.run imports jax early).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
from repro.launch.dryrun import build_lowerable
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.launch.mesh import make_mesh_from_config, mesh_config

cfg = get_config("qwen3-4b")
shape = InputShape("train_4k", seq_len=4096, global_batch=256, kind="train")
mc = mesh_config()
mesh = make_mesh_from_config(mc)
out = {}
for step in ["zo_fl", "first_order"]:
    jf, args = build_lowerable(cfg, shape, mesh, mc, step)
    ma = jf.lower(*args).compile().memory_analysis()
    out[step] = dict(
        argument_bytes=int(ma.argument_size_in_bytes),
        temp_bytes=int(ma.temp_size_in_bytes),
        peak_est_bytes=int(ma.argument_size_in_bytes
                           + ma.output_size_in_bytes
                           + ma.temp_size_in_bytes - ma.alias_size_in_bytes))
print("RESULT " + json.dumps(out))
"""


def run(quick: bool = True, seed: int = 0) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                          capture_output=True, text=True, timeout=1800)
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    if not line:
        raise RuntimeError(f"child failed:\n{proc.stderr[-2000:]}")
    out = json.loads(line[0][len("RESULT "):])
    for step, m in out.items():
        print(f"  {step:12s} temp={m['temp_bytes'] / 1e9:7.2f} GB  "
              f"peak~{m['peak_est_bytes'] / 1e9:7.2f} GB /device")
    ratio = out["first_order"]["temp_bytes"] / max(
        1, out["zo_fl"]["temp_bytes"])
    print(f"  first-order temp / ZO temp = {ratio:.1f}x")
    return {"table": "memory_footprint", "arch": "qwen3-4b",
            "per_device": out, "temp_ratio": ratio,
            "claim_zo_saves_activation_memory": bool(ratio > 1.5)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    from benchmarks import common as C
    print("saved:", C.save_result("memory_footprint", res))


if __name__ == "__main__":
    main()
