"""Benchmark orchestrator: ``python -m benchmarks.run [--full] [--only X]``.

Runs one benchmark per paper table/figure (DESIGN.md §6), writes each
result to runs/bench/<name>.json and prints a claims summary.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

from benchmarks import common as C

SUITES = [
    "microbench",        # kernel allclose + timings (fast, fails loud)
    "comm_cost",         # §2.3: >=1000x communication saving
    "error_floor",       # Thm 2.1: steady-state error grows with T
    "fig3_gradip",       # Claim 2: GradIP phenomenon
    "table1_noniid",     # Claim 1: MEERKAT > baselines, Non-IID
    "table5_iid",        # Claim 1: MEERKAT > Full-FedZO, IID
    "fig2_highfreq",     # Claim 1: T=1 closes the IID/Non-IID gap
    "table7_sparsity",   # Table 7: robust across densities
    "table6_vp",         # Claim 3: MEERKAT-VP > MEERKAT > random
    "table12_transfer",  # Tables 12/13: mask transferability
    "table11_decomfl",   # Table 11: MEERKAT vs DeComFL (dimension-free ZO)
    "memory_footprint",  # §1 memory claim: ZO vs backprop activation memory
    "ablation_server_momentum",  # beyond-paper: FedAvgM on sparse updates
    "ablation_multi_dir",        # beyond-paper: K-direction ZO estimator
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (slow); default is quick mode")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of suites")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()

    names = a.only.split(",") if a.only else SUITES
    summary = []
    for name in names:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            res = mod.run(quick=not a.full, seed=a.seed)
            res["wall_s"] = round(time.time() - t0, 1)
            path = C.save_result(name, res)
            claims = {k: v for k, v in res.items()
                      if k.startswith("claim") or k == "all_ok"}
            summary.append((name, "ok", claims, res["wall_s"]))
            print(f"saved: {path}")
        except Exception as e:  # noqa: BLE001 — keep the sweep going
            traceback.print_exc()
            summary.append((name, f"ERROR: {e}", {},
                            round(time.time() - t0, 1)))

    print("\n" + "=" * 72)
    print("BENCHMARK SUMMARY")
    print("=" * 72)
    n_claims = n_pass = 0
    for name, status, claims, wall in summary:
        print(f"{name:18s} {status:6s} ({wall:7.1f}s)")
        for k, v in claims.items():
            n_claims += 1
            n_pass += bool(v)
            print(f"    {'PASS' if v else 'MISS'}  {k}")
    print(f"\nclaims: {n_pass}/{n_claims} validated")


if __name__ == "__main__":
    main()
