"""Paper Table 5: MEERKAT vs Full-FedZO under IID client data at the same
synchronization frequency.  Claim: the sparsity advantage is not a Non-IID
artifact — MEERKAT also wins under IID."""
from __future__ import annotations

import argparse

from benchmarks import common as C
from benchmarks.table1_noniid import METHOD_LR


def run(quick: bool = True, seed: int = 0, budget: int = 400) -> dict:
    Ts = [10, 30] if quick else [10, 30, 50, 100]
    prob = C.build_problem(seed=seed)
    rows = []
    for T in Ts:
        rounds = max(1, budget // T)
        for method in ["full", "meerkat"]:
            srv = C.make_server(prob, method, partition="iid", T=T,
                                lr=METHOD_LR[method], seed=seed)
            (_, dt) = C.timed(srv.run, rounds)
            m = C.final_metrics(srv, prob)
            rows.append(dict(method=method, T=T, rounds=rounds,
                             acc=m["acc"], loss=m["loss"], wall_s=round(dt, 1)))
            print(f"  T={T:3d} {method:8s} acc={m['acc']:.3f} "
                  f"loss={m['loss']:.3f} ({dt:.0f}s)")
    ok = all(
        max(r["acc"] for r in rows if r["T"] == T and r["method"] == "meerkat")
        >= max(r["acc"] for r in rows if r["T"] == T and r["method"] == "full")
        for T in Ts)
    return {"table": "table5_iid", "rows": rows,
            "claim_meerkat_beats_full_iid": bool(ok)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    res = run(quick=not a.full, seed=a.seed)
    print("saved:", C.save_result("table5_iid", res))


if __name__ == "__main__":
    main()
